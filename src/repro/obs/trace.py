"""Span-based tracing with Chrome-trace JSON export.

Spans are host-side wall-clock intervals around already-executed work
(jit dispatch + device sync included) — they never enter a traced
program. Nesting comes from a plain stack: spans opened inside an open
span become its children in the exported view (Chrome trace renders
containment on one track).

The export is the Trace Event Format's complete-event ("ph": "X") JSON,
loadable in Perfetto / chrome://tracing: microsecond timestamps relative
to tracer start, one pid per run, tid 0 for the main host thread.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Dict, List, Optional


@dataclasses.dataclass
class SpanRecord:
    name: str
    t_start: float          # seconds since tracer start (perf_counter)
    dur: float              # seconds
    depth: int
    args: Dict = dataclasses.field(default_factory=dict)


class _SpanCtx:
    __slots__ = ("_tracer", "name", "args", "_t0", "_depth")

    def __init__(self, tracer: "Tracer", name: str, args: Dict):
        self._tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self):
        self._depth = len(self._tracer._stack)
        self._tracer._stack.append(self)
        self._t0 = time.perf_counter() - self._tracer._p0
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter() - self._tracer._p0
        stack = self._tracer._stack
        if stack and stack[-1] is self:
            stack.pop()
        self._tracer.spans.append(SpanRecord(
            self.name, self._t0, t1 - self._t0, self._depth, self.args))
        return False


class Tracer:
    def __init__(self):
        self.spans: List[SpanRecord] = []
        self._stack: List[_SpanCtx] = []
        self._p0 = time.perf_counter()
        self.t_epoch = time.time()          # wall time of tracer start

    def span(self, name: str, **args) -> _SpanCtx:
        return _SpanCtx(self, name, args)


def chrome_trace_doc(spans: List[SpanRecord],
                     process_name: str = "repro",
                     pid: int = 0) -> Dict:
    """Trace Event Format document (Perfetto/chrome://tracing-loadable)."""
    events = [{
        "ph": "M", "pid": pid, "tid": 0, "name": "process_name",
        "args": {"name": process_name},
    }, {
        "ph": "M", "pid": pid, "tid": 0, "name": "thread_name",
        "args": {"name": "host"},
    }]
    for s in spans:
        events.append({
            "ph": "X",
            "name": s.name,
            "cat": s.name.split(".", 1)[0],
            "ts": round(s.t_start * 1e6, 3),
            "dur": round(s.dur * 1e6, 3),
            "pid": pid,
            "tid": 0,
            "args": s.args,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, spans: List[SpanRecord],
                       process_name: str = "repro") -> None:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(chrome_trace_doc(spans, process_name=process_name), f)


def load_chrome_trace(path: str) -> Optional[Dict]:
    with open(path) as f:
        return json.load(f)
