"""Unified telemetry: metrics registry, span tracing, sinks, memory
probes, and the run-artifact report CLI (``python -m repro.obs.report``).

Everything records host-side on already-returned values: telemetry-on is
bit-identical to telemetry-off on every traced program; telemetry-off
(``NULL``) is a preallocated no-op object. See ``obs/telemetry.py``.
"""
from repro.obs.memory import (
    MemoryProbe,
    device_memory_stats,
    live_array_bytes,
    modeled_peak_bytes,
    modeled_peak_of,
)
from repro.obs.metrics import (
    DEFAULT_BYTES_BUCKETS,
    DEFAULT_LATENCY_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.sinks import (
    InMemorySink,
    JSONLSink,
    PrometheusTextfileSink,
    Sink,
)
from repro.obs.telemetry import NULL, NullTelemetry, Telemetry, make_telemetry
from repro.obs.trace import (
    SpanRecord,
    Tracer,
    chrome_trace_doc,
    load_chrome_trace,
    write_chrome_trace,
)

__all__ = [
    "NULL", "NullTelemetry", "Telemetry", "make_telemetry",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "DEFAULT_LATENCY_BUCKETS", "DEFAULT_BYTES_BUCKETS",
    "Sink", "JSONLSink", "InMemorySink", "PrometheusTextfileSink",
    "SpanRecord", "Tracer", "chrome_trace_doc", "write_chrome_trace",
    "load_chrome_trace",
    "MemoryProbe", "live_array_bytes", "device_memory_stats",
    "modeled_peak_bytes", "modeled_peak_of",
]
