"""Render a telemetry JSONL run artifact into summary tables.

    PYTHONPATH=src python -m repro.obs.report run.jsonl

Detects what the run contained and renders the matching sections:

* ``round`` events  -> federation/training round table (loss, bytes
  up/down, survivors/cohort, stragglers, estimator route)
* ``async_round`` events -> async federation table (loss, staleness,
  buffer occupancy, useful-vs-discarded compute, utilization) plus the
  staleness histogram from the final ``metrics`` snapshot
* ``request`` events -> serving table (TTFT, latency, tok/s per request)
  plus aggregate percentiles and the adapter-cache hit rate from the
  final ``metrics`` snapshot
* ``memory`` events  -> modeled-vs-measured residency lines
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional


def load_events(path: str) -> List[Dict]:
    events = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: bad JSONL line ({e})")
    return events


def _fmt(v, nd=4):
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.{nd}g}"
    return str(v)


def _table(headers: List[str], rows: List[List]) -> str:
    cells = [headers] + [[_fmt(c) for c in r] for r in rows]
    widths = [max(len(row[i]) for row in cells) for i in range(len(headers))]
    out = ["  ".join(h.ljust(w) for h, w in zip(cells[0], widths))]
    out.append("  ".join("-" * w for w in widths))
    for r in cells[1:]:
        out.append("  ".join(c.rjust(w) for c, w in zip(r, widths)))
    return "\n".join(out)


def _last_metrics(events: List[Dict]) -> Dict:
    for e in reversed(events):
        if e.get("kind") == "metrics":
            return e.get("metrics", {})
    return {}


def round_summary(events: List[Dict]) -> Optional[str]:
    rounds = [e for e in events if e.get("kind") == "round"]
    if not rounds:
        return None
    evals = {e.get("round"): e for e in events if e.get("kind") == "eval"}
    rows = []
    for e in rounds:
        r = e.get("round")
        ev = evals.get(r, {})
        surv = e.get("survivors")
        coh = e.get("cohort")
        rows.append([
            r, e.get("loss"), e.get("jvp_abs_mean"), e.get("delta_norm"),
            e.get("bytes_up"), e.get("bytes_down"),
            (f"{surv}/{coh}" if surv is not None else "-"),
            e.get("stragglers"), e.get("surviving_mask_units"),
            ev.get("acc"),
        ])
    header = ["round", "loss", "jvp_abs", "delta_norm", "bytes_up",
              "bytes_down", "surv/cohort", "stragglers", "mask_units", "acc"]
    total_up = sum(e.get("bytes_up") or 0 for e in rounds)
    total_down = sum(e.get("bytes_down") or 0 for e in rounds)
    lines = [f"rounds: {len(rounds)}  "
             f"bytes_up_total={total_up}  bytes_down_total={total_down}",
             _table(header, rows)]
    return "\n".join(lines)


def async_summary(events: List[Dict]) -> Optional[str]:
    rounds = [e for e in events if e.get("kind") == "async_round"]
    if not rounds:
        return None
    rows = [[e.get("version"), e.get("sim_time_s"), e.get("loss"),
             e.get("staleness_mean"), e.get("buffer_occupancy"),
             e.get("in_flight"), e.get("bytes_up"),
             e.get("utilization")] for e in rounds]
    header = ["version", "sim_t", "loss", "stale_mean", "buffer",
              "in_flight", "bytes_up", "util"]
    last = rounds[-1]
    lines = [f"versions: {len(rounds)}  "
             f"sim_wall={_fmt(last.get('sim_time_s'))}s  "
             f"useful_compute={_fmt(last.get('useful_compute_s'))}s  "
             f"discarded={_fmt(last.get('discarded_compute_s'))}s  "
             f"utilization={_fmt(last.get('utilization'))}",
             _table(header, rows)]
    m = _last_metrics(events)
    h = m.get("histograms", {}).get("fl.async.staleness")
    if h and h.get("count"):
        lines.append(f"staleness: mean={_fmt(h['mean'])} "
                     f"p50={_fmt(h['p50'])} p95={_fmt(h['p95'])} "
                     f"max={_fmt(h.get('max'))}")
    counters = m.get("counters", {})
    used = counters.get("fl.async.updates_used")
    if used is not None:
        lines.append(f"updates: {int(used)} used / "
                     f"{int(counters.get('fl.async.updates_discarded', 0))} "
                     f"discarded")
    return "\n\n".join(lines)


def serving_summary(events: List[Dict]) -> Optional[str]:
    reqs = [e for e in events if e.get("kind") == "request"]
    if not reqs:
        return None
    rows = [[e.get("request_id"), e.get("adapter_id"), e.get("prompt_len"),
             e.get("gen_tokens"), e.get("ttft_s"), e.get("latency_s"),
             e.get("tok_per_sec")] for e in reqs]
    header = ["request", "adapter", "prompt", "tokens", "ttft_s",
              "latency_s", "tok/s"]
    lines = [f"requests: {len(reqs)}", _table(header, rows)]

    m = _last_metrics(events)
    hist = m.get("histograms", {})
    agg = []
    for name, label in (("serve.ttft_s", "TTFT"),
                        ("serve.request_latency_s", "latency")):
        h = hist.get(name)
        if h and h.get("count"):
            agg.append(f"{label}: mean={_fmt(h['mean'])}s "
                       f"p50={_fmt(h['p50'])}s p95={_fmt(h['p95'])}s "
                       f"p99={_fmt(h['p99'])}s")
    gauges = m.get("gauges", {})
    if "serve.decode_tok_per_sec" in gauges:
        agg.append("steady-state decode: "
                   f"{_fmt(gauges['serve.decode_tok_per_sec'])} tok/s")
    counters = m.get("counters", {})
    hits = counters.get("adapter_cache.hits", 0)
    misses = counters.get("adapter_cache.misses", 0)
    if hits or misses:
        agg.append(f"adapter cache: {int(hits)} hits / {int(misses)} misses "
                   f"/ {int(counters.get('adapter_cache.evictions', 0))} "
                   f"evictions (hit rate "
                   f"{hits / max(1, hits + misses):.3f})")
    if agg:
        lines.append("\n".join(agg))
    return "\n\n".join(lines)


def memory_summary(events: List[Dict]) -> Optional[str]:
    mems = [e for e in events if e.get("kind") == "memory"]
    if not mems:
        return None
    rows = [[e.get("label"), e.get("live_bytes"),
             e.get("device_bytes_in_use"), e.get("modeled_peak_bytes")]
            for e in mems]
    return _table(["probe", "live_bytes", "device_in_use", "modeled_peak"],
                  rows)


def render(path: str) -> str:
    events = load_events(path)
    meta = next((e for e in events if e.get("kind") == "run_meta"), {})
    sections = [f"telemetry report: {path}"]
    if meta:
        fields = {k: v for k, v in meta.items()
                  if k not in ("ts", "kind")}
        sections[0] += "\n" + "  ".join(f"{k}={v}"
                                        for k, v in sorted(fields.items()))
    for title, body in (("rounds", round_summary(events)),
                        ("async federation", async_summary(events)),
                        ("serving", serving_summary(events)),
                        ("memory", memory_summary(events))):
        if body:
            sections.append(f"== {title} ==\n{body}")
    if len(sections) == 1:
        sections.append(f"(no round/request/memory events in "
                        f"{len(events)} events)")
    return "\n\n".join(sections)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="summarize a telemetry JSONL run artifact")
    ap.add_argument("jsonl", help="path to the run's JSONL event log")
    args = ap.parse_args(argv)
    print(render(args.jsonl))
    return 0


if __name__ == "__main__":
    sys.exit(main())
