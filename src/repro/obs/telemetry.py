"""The telemetry facade: one object per run, threaded through the three
workloads (train / federate / serve).

Contract (the whole point of the design):

* ALL recording is host-side, outside jit, on values the traced programs
  already returned. ``Telemetry`` never closes over anything a tracer
  sees, so telemetry-on leaves every jaxpr/HLO and every numeric
  bit-identical (tests/test_telemetry_neutrality.py + the
  ``repro.analysis`` telemetry-neutrality rule assert this).
* Telemetry-off is ``NULL`` — a singleton whose instruments and spans are
  preallocated no-ops: a disabled hot loop does zero per-step allocation
  (``NULL.span(...)`` and ``NULL.counter(...)`` return module-level
  singletons; ``inc``/``observe``/``__enter__`` are empty methods).

Usage:

    tel = Telemetry(run_id="fed-0", sinks=[JSONLSink("run.jsonl")])
    c = tel.counter("fl.bytes_up")          # handle, create once
    with tel.span("fl.round", round=3):
        ...                                 # host work incl. jit dispatch
    c.add(report.bytes_up)
    tel.event("round", round=3, loss=float(metrics["loss"]))
    tel.export_chrome_trace("trace.json")   # Perfetto-loadable
    tel.close()                             # final metrics snapshot event
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

from repro.obs.metrics import MetricsRegistry
from repro.obs.sinks import (
    InMemorySink,
    JSONLSink,
    PrometheusTextfileSink,
    Sink,
)
from repro.obs.trace import Tracer, write_chrome_trace


def _jsonable(v):
    """Coerce numpy/jax scalars and containers to plain JSON types.
    Conversion happens on HOST copies of already-computed values — it can
    force a device sync, never a recompute or a numeric change."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if hasattr(v, "item") and getattr(v, "ndim", None) in (0, None):
        try:
            return _jsonable(v.item())
        except Exception:
            return str(v)
    if hasattr(v, "tolist"):
        return _jsonable(v.tolist())
    return str(v)


class _NullInstrument:
    """Counter/gauge/histogram no-op, one shared instance."""
    __slots__ = ()
    name = "null"
    value = 0.0

    def inc(self, n: float = 1.0) -> None:
        pass

    add = inc

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def snapshot(self):
        return None


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_INSTRUMENT = _NullInstrument()
_NULL_SPAN = _NullSpan()


class NullTelemetry:
    """Disabled telemetry: every method returns a preallocated no-op."""
    enabled = False
    run_id = None
    sinks: List[Sink] = []

    def counter(self, name: str):
        return _NULL_INSTRUMENT

    def gauge(self, name: str):
        return _NULL_INSTRUMENT

    def histogram(self, name: str, buckets=None):
        return _NULL_INSTRUMENT

    def span(self, name: str, **args):
        return _NULL_SPAN

    def event(self, kind: str, **fields) -> None:
        pass

    def now(self) -> float:
        return 0.0

    def metrics_snapshot(self) -> Dict:
        return {}

    def emit_metrics(self) -> None:
        pass

    def export_chrome_trace(self, path: str) -> None:
        pass

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


NULL = NullTelemetry()


class Telemetry:
    enabled = True

    def __init__(self, run_id: Optional[str] = None,
                 sinks: Sequence[Sink] = (), workload: Optional[str] = None):
        self.run_id = run_id or f"run-{int(time.time() * 1e3):x}"
        self.registry = MetricsRegistry()
        self.tracer = Tracer()
        self.sinks = list(sinks)
        for s in self.sinks:
            if isinstance(s, PrometheusTextfileSink):
                s.bind_registry(self.registry)
        self._closed = False
        if workload:
            self.event("run_meta", workload=workload)

    # -- instruments ---------------------------------------------------------

    def counter(self, name: str):
        return self.registry.counter(name)

    def gauge(self, name: str):
        return self.registry.gauge(name)

    def histogram(self, name: str, buckets=None):
        return self.registry.histogram(name, buckets)

    # -- spans / events ------------------------------------------------------

    def span(self, name: str, **args):
        return self.tracer.span(name, **args)

    def now(self) -> float:
        return time.perf_counter()

    def event(self, kind: str, **fields) -> None:
        rec = {"ts": time.time(), "run_id": self.run_id, "kind": kind}
        for k, v in fields.items():
            rec[k] = _jsonable(v)
        for s in self.sinks:
            s.emit(rec)

    def metrics_snapshot(self) -> Dict:
        return self.registry.snapshot()

    def emit_metrics(self) -> None:
        self.event("metrics", metrics=self.metrics_snapshot())

    # -- lifecycle -----------------------------------------------------------

    def export_chrome_trace(self, path: str) -> None:
        write_chrome_trace(path, self.tracer.spans,
                           process_name=self.run_id)

    def flush(self) -> None:
        for s in self.sinks:
            s.flush()

    def close(self) -> None:
        """Emit the final metrics snapshot and close every sink. Idempotent
        (runs that crash mid-way may close twice via finally blocks)."""
        if self._closed:
            return
        self._closed = True
        self.emit_metrics()
        for s in self.sinks:
            s.close()


def make_telemetry(jsonl: Optional[str] = None,
                   prometheus: Optional[str] = None,
                   in_memory: bool = False,
                   run_id: Optional[str] = None,
                   workload: Optional[str] = None):
    """Convenience constructor used by the launch CLIs. Returns ``NULL``
    when no sink is requested — callers hold one object either way."""
    sinks: List[Sink] = []
    if jsonl:
        sinks.append(JSONLSink(jsonl))
    if prometheus:
        sinks.append(PrometheusTextfileSink(prometheus))
    if in_memory:
        sinks.append(InMemorySink())
    if not sinks:
        return NULL
    return Telemetry(run_id=run_id, sinks=sinks, workload=workload)
