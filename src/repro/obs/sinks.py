"""Pluggable event sinks.

Every telemetry event is one flat-ish JSON-safe dict with at least
``ts`` (unix seconds), ``run_id`` and ``kind``. Sinks receive events as
they are emitted:

  JSONLSink              append-only JSON-lines file — the exportable run
                         artifact ``repro.obs.report`` renders and
                         ``benchmarks/check_schemas.py`` validates
  PrometheusTextfileSink writes a metrics exposition snapshot on flush
                         (node-exporter textfile-collector format)
  InMemorySink           list of events, for tests and benches
"""
from __future__ import annotations

import json
import os
from typing import Dict, List


class Sink:
    def emit(self, event: Dict) -> None:     # pragma: no cover - interface
        raise NotImplementedError

    def flush(self) -> None:
        pass

    def close(self) -> None:
        self.flush()


class InMemorySink(Sink):
    def __init__(self):
        self.events: List[Dict] = []

    def emit(self, event: Dict) -> None:
        self.events.append(event)

    def by_kind(self, kind: str) -> List[Dict]:
        return [e for e in self.events if e.get("kind") == kind]


class JSONLSink(Sink):
    """One JSON object per line, flushed per event (the run artifact must
    survive a crashed run — partial logs are still loadable)."""

    def __init__(self, path: str):
        self.path = str(path)
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(self.path, "w")

    def emit(self, event: Dict) -> None:
        self._f.write(json.dumps(event, separators=(",", ":")) + "\n")
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


class PrometheusTextfileSink(Sink):
    """Metrics snapshot in Prometheus exposition format. Events pass
    through untouched; ``flush``/``close`` (called by ``Telemetry``)
    rewrite the textfile from the registry's current state."""

    def __init__(self, path: str):
        self.path = str(path)
        self._registry = None

    def bind_registry(self, registry) -> None:
        self._registry = registry

    def emit(self, event: Dict) -> None:
        pass

    def flush(self) -> None:
        if self._registry is None:
            return
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(self.path, "w") as f:
            f.write(self._registry.prometheus_text())
