"""Host-side metrics registry: counters, gauges, fixed-bucket histograms.

Instruments are plain Python objects mutated OUTSIDE jit on
already-returned values — nothing here ever appears in a traced program,
which is the subsystem's core contract (telemetry-on must leave every
jaxpr and every numeric bit-identical; see tests/test_telemetry_neutrality).

Histograms use fixed buckets (Prometheus-style cumulative-le semantics)
so percentile queries are O(buckets) with bounded memory no matter how
many observations arrive: p50/p95/p99 are estimated by linear
interpolation inside the bucket containing the target rank — exact when
observations are unique bucket edges, conservative otherwise.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, Optional, Sequence, Tuple

# default latency buckets (seconds): 100us .. 100s, ~log-spaced. Wide
# enough for a CPU-interpret decode step and a full federation round.
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0,
)
# default byte-size buckets: 64B .. 4GiB, power-of-4 spaced
DEFAULT_BYTES_BUCKETS: Tuple[float, ...] = tuple(
    float(64 * 4 ** i) for i in range(14))


class Counter:
    """Monotonically increasing count."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    add = inc

    def snapshot(self) -> float:
        return self.value


class Gauge:
    """Last-set value."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = float("nan")

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self) -> float:
        return self.value


class Histogram:
    """Fixed-bucket histogram with cumulative-le counts.

    ``buckets`` are upper edges; an implicit +inf bucket catches the
    overflow. ``percentile(q)`` walks the cumulative counts to the bucket
    holding rank q and interpolates linearly between its edges (the lowest
    edge interpolates from ``min``, the overflow bucket reports ``max``).
    """
    __slots__ = ("name", "buckets", "counts", "count", "sum", "min", "max")

    def __init__(self, name: str,
                 buckets: Optional[Sequence[float]] = None):
        self.name = name
        bs = tuple(sorted(buckets or DEFAULT_LATENCY_BUCKETS))
        if not bs:
            raise ValueError("histogram needs at least one bucket edge")
        self.buckets = bs
        self.counts = [0] * (len(bs) + 1)   # last = +inf overflow
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        lo, hi = 0, len(self.buckets)
        while lo < hi:                       # first edge >= v
            mid = (lo + hi) // 2
            if self.buckets[mid] < v:
                lo = mid + 1
            else:
                hi = mid
        self.counts[lo] += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")

    def percentile(self, q: float) -> float:
        """q in [0, 1]."""
        if not self.count:
            return float("nan")
        rank = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            prev_cum = cum
            cum += c
            if cum >= rank:
                if i == len(self.buckets):          # overflow bucket
                    return self.max
                hi = self.buckets[i]
                lo = self.buckets[i - 1] if i else min(self.min, hi)
                frac = (rank - prev_cum) / c
                est = lo + (hi - lo) * max(0.0, min(1.0, frac))
                # never report outside the observed range
                return max(self.min, min(self.max, est))
        return self.max

    def snapshot(self) -> Dict:
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else None,
            "max": self.max if self.count else None,
            "mean": self.mean if self.count else None,
            "p50": self.percentile(0.50) if self.count else None,
            "p95": self.percentile(0.95) if self.count else None,
            "p99": self.percentile(0.99) if self.count else None,
        }


class MetricsRegistry:
    """Name -> instrument, get-or-create. Creating the same name twice
    returns the same object (instrument handles are cached by callers at
    init time; re-lookup must not fork the series)."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._lock = threading.Lock()

    def counter(self, name: str) -> Counter:
        with self._lock:
            if name not in self._counters:
                self._counters[name] = Counter(name)
            return self._counters[name]

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            if name not in self._gauges:
                self._gauges[name] = Gauge(name)
            return self._gauges[name]

    def histogram(self, name: str,
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        with self._lock:
            if name not in self._histograms:
                self._histograms[name] = Histogram(name, buckets)
            return self._histograms[name]

    def snapshot(self) -> Dict:
        return {
            "counters": {n: c.snapshot() for n, c in self._counters.items()},
            "gauges": {n: g.snapshot() for n, g in self._gauges.items()
                       if g.value == g.value},      # skip never-set NaN
            "histograms": {n: h.snapshot()
                           for n, h in self._histograms.items()},
        }

    def prometheus_text(self) -> str:
        """Prometheus textfile-collector exposition (one snapshot)."""
        def esc(name):
            return name.replace(".", "_").replace("-", "_")

        lines = []
        for n, c in sorted(self._counters.items()):
            lines.append(f"# TYPE {esc(n)} counter")
            lines.append(f"{esc(n)} {c.value}")
        for n, g in sorted(self._gauges.items()):
            if g.value != g.value:
                continue
            lines.append(f"# TYPE {esc(n)} gauge")
            lines.append(f"{esc(n)} {g.value}")
        for n, h in sorted(self._histograms.items()):
            base = esc(n)
            lines.append(f"# TYPE {base} histogram")
            cum = 0
            for edge, c in zip(h.buckets, h.counts):
                cum += c
                lines.append(f'{base}_bucket{{le="{edge}"}} {cum}')
            lines.append(f'{base}_bucket{{le="+Inf"}} {h.count}')
            lines.append(f"{base}_sum {h.sum}")
            lines.append(f"{base}_count {h.count}")
        return "\n".join(lines) + ("\n" if lines else "")
