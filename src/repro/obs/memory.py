"""Memory probes: modeled-vs-measured residency in ONE artifact.

``repro.analysis`` / ``launch.hlo_analysis.peak_live_bytes`` give the
STATIC side — a buffer-liveness walk over compiled HLO bounding a
program's peak live bytes before anything runs. This module adds the
RUNTIME side — ``jax.live_arrays()`` totals and per-device
``memory_stats()`` sampled at probe points — and pairs the two in a
single ``memory`` telemetry event, so the real-TPU validation run
(ROADMAP item 6) reads modeled and measured residency from the same
JSONL row instead of reconciling two tools.

Probing is host-side and read-only: sampling allocates nothing on device
and never touches a traced program.
"""
from __future__ import annotations

from typing import Dict, Optional


def live_array_bytes() -> int:
    """Total bytes of every live device array in the process."""
    import jax

    total = 0
    for a in jax.live_arrays():
        try:
            total += int(a.nbytes)
        except Exception:       # deleted/donated arrays can race the walk
            continue
    return total


def device_memory_stats() -> Dict[str, Dict]:
    """Per-device allocator stats where the backend exposes them (TPU/GPU;
    the CPU backend returns none — the live-array total still applies)."""
    import jax

    out = {}
    for dev in jax.local_devices():
        try:
            stats = dev.memory_stats()
        except Exception:
            stats = None
        if stats:
            out[f"{dev.platform}:{dev.id}"] = {
                k: int(v) for k, v in stats.items()
                if isinstance(v, (int, float))}
    return out


def modeled_peak_bytes(compiled_or_text) -> Optional[float]:
    """Static peak-live-bytes of a compiled program (the PR 4 HLO
    liveness analyzer). Accepts a ``jax.stages.Compiled`` or HLO text."""
    from repro.launch.hlo_analysis import peak_live_bytes

    text = (compiled_or_text if isinstance(compiled_or_text, str)
            else compiled_or_text.as_text())
    try:
        return float(peak_live_bytes(text))
    except Exception:
        return None


def modeled_peak_of(jit_fn, *args, **kwargs) -> Optional[float]:
    """Lower+compile a jitted fn at the given avals and return its modeled
    peak. jax caches the executable, so a subsequent call at the same
    shapes reuses this compilation — probing costs no extra compile on
    the hot path."""
    try:
        compiled = jit_fn.lower(*args, **kwargs).compile()
    except Exception:
        return None
    return modeled_peak_bytes(compiled)


class MemoryProbe:
    """Samples runtime residency into gauges + ``memory`` events.

    ``sample(label)`` records live-array bytes (and device stats when
    available); pass ``modeled_bytes`` to pair the static number with the
    measurement in the same event — the modeled-vs-measured artifact.
    """

    def __init__(self, telemetry):
        self.telemetry = telemetry
        self._g_live = telemetry.gauge("mem.live_array_bytes")
        self._g_modeled = telemetry.gauge("mem.modeled_peak_bytes")

    def sample(self, label: str,
               modeled_bytes: Optional[float] = None) -> Dict:
        rec = {"label": label, "live_bytes": live_array_bytes()}
        stats = device_memory_stats()
        if stats:
            rec["device_stats"] = stats
            rec["device_bytes_in_use"] = sum(
                s.get("bytes_in_use", 0) for s in stats.values())
        if modeled_bytes is not None:
            rec["modeled_peak_bytes"] = float(modeled_bytes)
            self._g_modeled.set(float(modeled_bytes))
        self._g_live.set(rec["live_bytes"])
        self.telemetry.event("memory", **rec)
        return rec
