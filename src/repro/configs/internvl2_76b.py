"""internvl2-76b — VLM: InternViT frontend (STUB) + InternLM2-76B backbone.
[arXiv:2404.16821]

Per the carve-out, only the language backbone is implemented; `input_specs`
provides precomputed patch embeddings at d_model (projector output)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    attn_pattern="full",
    frontend="vision",
    n_frontend_tokens=256,
    notes="ViT+projector stubbed to 256 patch embeddings; full attention -> long_500k skipped",
)
