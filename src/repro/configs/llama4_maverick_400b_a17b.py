"""llama4-maverick-400b-a17b — MoE 128 experts top-1 + shared expert,
early-fusion multimodal (image tokens arrive as STUB embeddings).
[hf:meta-llama/Llama-4-Scout-17B-16E family card]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,             # per-expert hidden (matches pool spec)
    vocab=202048,
    attn_pattern="full",
    moe=MoEConfig(n_experts=128, top_k=1, d_expert=8192, n_shared_experts=1),
    frontend="vision",
    n_frontend_tokens=128, # early-fusion image tokens (stub embeddings)
    notes="top-1 routing + shared expert; full attention in this config -> long_500k skipped",
)
