"""gemma3-12b — dense decoder, 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt family card]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    attn_pattern="local_global",
    local_global_ratio=5,
    window=1024,
    rope_theta=1e6,
    tie_embeddings=True,
    notes="5 local (w=1024) : 1 global; sub-quadratic decode -> long_500k runs",
)
