"""rwkv6-1.6b (Finch) — attention-free RNN with data-dependent decay.
[arXiv:2404.05892]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,           # wkv heads = d_model / head_dim
    n_kv_heads=32,
    d_ff=7168,
    vocab=65536,
    ssm=SSMConfig(kind="rwkv6", head_dim=64),
    notes="attn-free; O(1) decode state -> long_500k runs; SPRY splits LoRA on r/k/v/g/o projections",
)
