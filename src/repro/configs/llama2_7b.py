"""llama2-7b — the paper's primary billion-scale evaluation model.
[arXiv:2307.09288] (paper uses 4-bit quantized + LoRA; we use bf16 + LoRA,
see DESIGN.md §2)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama2-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab=32000,
    attn_pattern="full",
    notes="paper's own model; used for the faithful-repro memory benchmark",
)
