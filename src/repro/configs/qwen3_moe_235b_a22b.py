"""qwen3-moe-235b-a22b — MoE 128 experts top-8, GQA kv=4.
[hf:Qwen/Qwen3-30B-A3B family card]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,             # per-expert hidden (matches pool spec)
    vocab=151936,
    attn_pattern="full",
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=1536),
    notes="expert-parallel over model axis; full attention -> long_500k skipped",
)
