"""h2o-danube-3-4b — llama/mistral-style dense decoder with sliding-window attn.
[arXiv:2401.16818]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab=32000,
    attn_pattern="swa",
    window=4096,
    notes="SWA w=4096 -> long_500k runs with ring-buffer cache",
)
