"""Architecture registry: ``get_config(arch_id)`` / ``--arch <id>``."""
from __future__ import annotations

import importlib

from repro.configs.base import (
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    SpryConfig,
    reduce_config,
)

# arch_id -> module name. The first 10 are the assigned pool; the last two are
# the paper's own evaluation models.
_ARCH_MODULES = {
    "command-r-plus-104b": "command_r_plus_104b",
    "gemma3-12b": "gemma3_12b",
    "internvl2-76b": "internvl2_76b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "whisper-tiny": "whisper_tiny",
    "gemma3-27b": "gemma3_27b",
    "zamba2-1.2b": "zamba2_1_2b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "roberta-large-lora": "roberta_large_lora",
    "llama2-7b": "llama2_7b",
}

ASSIGNED_ARCHS = tuple(list(_ARCH_MODULES)[:10])
ALL_ARCHS = tuple(_ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.CONFIG


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> bool:
    """Contract from the assignment: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True
