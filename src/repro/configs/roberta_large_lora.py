"""roberta-large (355M) — the paper's primary sub-billion evaluation model.
[arXiv:1907.11692] Finetuned with LoRA r=1, alpha=1 (paper Appendix B).

Implemented here as a causal-LM-style stack with a classification head (the
paper's tasks are sequence classification); bidirectionality is immaterial to
SPRY's algorithmic behaviour and is noted as an adaptation in DESIGN.md.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="roberta-large-lora",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=50265,
    attn_pattern="full",
    use_bias=True,
    norm="layernorm",
    act="gelu",
    n_classes=4,
    notes="paper's own model; used for the faithful-repro benchmarks",
)
