"""command-r-plus-104b — dense GQA decoder, no biases.
[hf:CohereForAI/c4ai-command-r-v01]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab=256000,
    attn_pattern="full",
    use_bias=False,
    tie_embeddings=True,
    notes="GQA kv=8, no-bias; pure full attention -> long_500k skipped (DESIGN.md §5)",
)
