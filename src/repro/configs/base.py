"""Config dataclasses: model architectures, input shapes, FL settings.

Every assigned architecture gets one module in this package exporting
``CONFIG: ModelConfig`` with the exact published dimensions (source cited in
the module docstring). ``reduce_config`` derives the CPU smoke-test variant
(2 layers, d_model<=512, <=4 experts) from the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    n_shared_experts: int = 0     # dense experts always applied (llama4 style)
    router_chunk: int = 2048      # token-chunked dispatch (memory bound)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str                     # 'rwkv6' | 'mamba2'
    state_dim: int = 64           # mamba2 N
    head_dim: int = 64
    conv_kernel: int = 4          # mamba2 depthwise conv width
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None           # default d_model // n_heads
    # --- attention pattern ---
    attn_pattern: str = "full"                # full | swa | local_global
    window: int = 4096
    local_global_ratio: int = 0               # gemma3: 5 -> every 6th layer global
    # --- family extensions ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_attn_every: int = 0                # zamba2: shared attn after every N blocks
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0                      # precomputed frame embeddings length
    # --- modality frontend stub (vlm / audio) ---
    frontend: Optional[str] = None            # 'vision' | 'audio'
    n_frontend_tokens: int = 0                # image patch tokens prepended
    # --- misc ---
    norm: str = "rmsnorm"
    act: str = "silu"
    use_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1e4
    param_dtype: str = "bfloat16"
    n_classes: int = 0                        # >0 adds a classifier head (FL tasks)
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if the architecture supports 500k-token decode structurally
        (bounded window / recurrent state)."""
        return (
            self.family in ("ssm", "hybrid")
            or self.attn_pattern in ("swa", "local_global")
        )

    def is_global_layer(self, i: int) -> bool:
        if self.attn_pattern == "full":
            return True
        if self.attn_pattern == "swa":
            return False
        # local_global: every (ratio+1)-th layer is global (gemma3: 5 local : 1 global)
        return (i % (self.local_global_ratio + 1)) == self.local_global_ratio

    def n_param_estimate(self) -> float:
        """Rough total parameter count (for roofline MODEL_FLOPS = 6·N·D)."""
        d, hd = self.d_model, self.hd
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        if self.family == "ssm" and self.ssm and self.ssm.kind == "rwkv6":
            attn = 5 * d * d + d * d  # r,k,v,g,w projections + output
        if self.moe is not None:
            ffn = 3 * d * self.moe.d_expert * (self.moe.n_experts + self.moe.n_shared_experts)
        else:
            ffn = 3 * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        total = self.n_layers * per_layer + emb
        if self.encoder_layers:
            total += self.encoder_layers * (2 * attn + ffn + 3 * d)
        return float(total)

    def n_active_param_estimate(self) -> float:
        """Active params per token (MoE counts top_k + shared experts only)."""
        if self.moe is None:
            return self.n_param_estimate()
        d = self.d_model
        hd = self.hd
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        ffn = 3 * d * self.moe.d_expert * (self.moe.top_k + self.moe.n_shared_experts)
        per_layer = attn + ffn + 2 * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return float(self.n_layers * per_layer + emb)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str          # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", "train", 4_096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k": InputShape("long_500k", "decode", 524_288, 1),
}


# ---------------------------------------------------------------------------
# SPRY / FL configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SpryConfig:
    """Hyperparameters of the paper's algorithm (Alg. 1 + §3)."""
    n_clients_per_round: int = 16        # M
    n_total_clients: int = 100
    sampling_rate: float = 0.16          # s
    k_perturbations: int = 1             # K (paper default)
    tangent_batch: int | None = None     # None = all K tangents in one batched
                                         # pass (one primal); 1 = sequential
                                         # jvp per perturbation (seed path);
                                         # 1<b<K = chunked groups of b
    fused_contraction: bool = False      # contract the final mixer site's K
                                         # tangent outputs against the
                                         # post-head cotangent in-kernel
                                         # (takes effect when the task loss
                                         # declares a fused site — see
                                         # core/forward_grad.py::SplitLoss)
    local_lr: float = 1e-4               # eta_l
    server_lr: float = 1e-2              # eta
    server_opt: str = "fedyogi"          # fedyogi | fedadam | fedavg | fedsgd | fedadagrad
    client_opt: str = "sgd"              # sgd | adam | adamw
    comm_mode: str = "per_epoch"         # per_epoch | per_iteration
    local_iters: int = 1                 # iterations per round inside the jitted step
    microbatch_size: int | None = None   # grad-accumulation chunk (None = full batch)
    jvp_clip: float | None = None        # beyond-paper: clamp jvp scalars (stability)
    lora_rank: int = 1                   # paper default r=1, alpha=1
    lora_alpha: float = 1.0
    lora_targets: Tuple[str, ...] = ("wq", "wv")
    peft: str = "lora"                   # lora | ia3 | bitfit | classifier_only
    dirichlet_alpha: float = 0.1         # data heterogeneity (paper: 1.0 hom / 0.1 het)
    seed: int = 0


# ---------------------------------------------------------------------------
# Reduced variants for CPU smoke tests
# ---------------------------------------------------------------------------

def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """2 layers, d_model<=512, <=4 experts — same family, runnable on CPU."""
    n_heads = min(cfg.n_heads, 4)
    # preserve the GQA ratio qualitatively
    n_kv = max(1, min(cfg.n_kv_heads, n_heads if cfg.n_kv_heads >= cfg.n_heads else 2))
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe,
            n_experts=min(cfg.moe.n_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            d_expert=128,
            router_chunk=64,
        )
    ssm = None
    if cfg.ssm is not None:
        ssm = dataclasses.replace(cfg.ssm, head_dim=32, state_dim=16)
    return dataclasses.replace(
        cfg,
        n_layers=2,
        d_model=256,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=64,
        d_ff=512,
        vocab=512,
        window=64,
        moe=moe,
        ssm=ssm,
        hybrid_attn_every=1 if cfg.hybrid_attn_every else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=16 if cfg.encoder_seq else 0,
        n_frontend_tokens=8 if cfg.n_frontend_tokens else 0,
        param_dtype="float32",
        n_classes=cfg.n_classes or 4,
    )
