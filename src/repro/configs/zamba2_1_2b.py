"""zamba2-1.2b — Mamba2 backbone with a SHARED attention block interleaved.
[arXiv:2411.15242]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    ssm=SSMConfig(kind="mamba2", state_dim=64, head_dim=64, expand=2),
    hybrid_attn_every=6,   # shared-weight attention block applied every 6 mamba blocks
    window=4096,           # the shared attn block uses a bounded window for 500k decode
    attn_pattern="swa",
    notes="Mamba2 + shared attn; recurrent state + windowed attn -> long_500k runs",
)
