"""whisper-tiny — encoder-decoder; mel/conv frontend STUBBED to frame embeddings.
[arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-tiny",
    family="audio",
    n_layers=4,            # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab=51865,
    attn_pattern="full",
    encoder_layers=4,
    encoder_seq=1500,      # precomputed conv frame embeddings (stub)
    frontend="audio",
    use_bias=True,
    rope_theta=0.0,        # whisper uses absolute (sinusoidal) positions
    norm="layernorm",
    act="gelu",
    notes="enc-dec; decode shapes lower the decoder w/ cross-attn memory; long_500k skipped (full attn)",
)
