#!/usr/bin/env bash
# Tier-1 test entry point (see ROADMAP.md).
#
#   ./test.sh              fast subset (-m "not slow") — the CI gate
#   FULL=1 ./test.sh       entire suite, including slow integration tests
#   ./test.sh tests/foo.py pass-through of extra pytest args
#
# Env idiom follows SNIPPETS.md (olmax test.sh): force the CPU backend and a
# fixed host-device count so sharding tests are reproducible anywhere.
set -euo pipefail
cd "$(dirname "$0")"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS=cpu
export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"

if [[ "${FULL:-0}" == "1" ]]; then
  exec python -m pytest -x -q "$@"
fi
exec python -m pytest -x -q -m "not slow" "$@"
