"""Paper Tables 2 & 3 — communication and computation cost accounting, at the
paper's own operating points (RoBERTa-Large LoRA: 48 trainable LoRA pairs,
~24k params per pair; M = 10 and 100 participating clients).
"""
from __future__ import annotations

from repro.fl import comm_cost, compute_cost

CASES = [("roberta-large", 24_576.0, 48)]
METHODS = ("fedavg", "fedsgd", "fedmezo", "fwdllm", "baffle", "spry")


def main(print_csv=True):
    rows = []
    for name, w_l, L in CASES:
        for M in (10, 100):
            for method in METHODS:
                for mode in ("per_epoch", "per_iteration"):
                    if method in ("fedavg",) and mode == "per_iteration":
                        continue
                    try:
                        cc = comm_cost(method, mode, w_l, L, M)
                    except ValueError:
                        continue
                    comp = compute_cost(method, mode, w_l, L, M, c=1e6, v=1e4,
                                        K=20 if method == "baffle" else
                                        (10 if method == "fwdllm" else 1))
                    rows.append((name, M, method, mode, cc, comp))
                    if print_csv:
                        print(f"table2_3_costs/{name}/M{M}/{method}/{mode},0,"
                              f"c2s={cc.client_to_server:.3e} "
                              f"s2c={cc.server_to_client:.3e} "
                              f"client_comp={comp.client_per_iter:.3e} "
                              f"server_comp={comp.server_per_round:.3e}")
    return rows


if __name__ == "__main__":
    main()
