"""Paper Figures 4-5 (Appendix G) — ablations:
  (a) splitting:      SPRY vs FedFGD (no split) vs FedAvgSplit
  (b) perturbations:  K = 1 vs 4
  (c) client count:   M = 2 / 4 / 8
  (d) LoRA rank:      r = 1 vs 8 (trainable-weight count, Fig 4c)
"""
from __future__ import annotations

import time

import jax

from repro.launch.train import run_training

BASE = dict(arch="roberta-large-lora", task="toy", rounds=30,
            total_clients=16, batch_size=8, dirichlet_alpha=0.1,
            eval_every=30, seed=0, local_lr=1e-2, server_lr=2e-2,
            log=lambda *a: None)


def main(print_csv=True):
    out = {}

    def run(tag, **kw):
        t0 = time.time()
        args = {**BASE, **kw}
        hist = run_training(**args)
        jax.clear_caches()
        acc = hist[-1]["acc"]
        out[tag] = acc
        if print_csv:
            print(f"fig5_ablation/{tag},{(time.time()-t0)/args['rounds']*1e6:.0f},"
                  f"acc={acc:.4f}")
        return acc

    # (a) splitting ablation (paper Fig 5c)
    run("split/spry", method="spry", clients_per_round=4)
    run("split/fedfgd_nosplit", method="fedfgd", clients_per_round=4)
    run("split/fedavgsplit", method="fedavgsplit", clients_per_round=4)
    # (b) K perturbations (paper Fig 5a)
    run("k_perturb/k1", method="spry", clients_per_round=4, k_perturbations=1)
    run("k_perturb/k4", method="spry", clients_per_round=4, k_perturbations=4)
    # (c) participating clients (paper Fig 5b)
    for m in (2, 4, 8):
        run(f"clients/m{m}", method="spry", clients_per_round=m)
    return out


if __name__ == "__main__":
    main()
