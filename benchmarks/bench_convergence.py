"""Paper Figure 3 — time/rounds to convergence: SPRY vs zero-order methods.

Reports rounds-to-target-accuracy and measured per-round wall time (the
paper's 1.5-28.6x per-round-computation claim maps to the wall-time column;
exact ratios differ on CPU but the ordering must hold: BAFFLE+ with K=20
perturbation pairs is the slowest per round).
"""
from __future__ import annotations

import time

import jax

from repro.launch.train import run_training

METHODS = ("spry", "fedmezo", "baffle", "fwdllm")


def rounds_to_target(history, target):
    for h in history:
        if h["acc"] >= target:
            return h["round"], h["t"]
    return None, None


def main(print_csv=True, rounds=50, target=0.60):
    out = {}
    for method in METHODS:
        t0 = time.time()
        extra = dict(k_perturbations=4, jvp_clip=10.0) if method == "spry" else {}
        hist = run_training(
            arch="roberta-large-lora", task="toy", method=method,
            rounds=rounds, clients_per_round=8, total_clients=16,
            batch_size=8, dirichlet_alpha=0.1, eval_every=5, seed=0,
            local_lr=1e-2, server_lr=2e-2, log=lambda *a: None, **extra)
        jax.clear_caches()
        wall = time.time() - t0
        r, t = rounds_to_target(hist, target)
        out[method] = dict(rounds_to_target=r, wall_per_round=wall / rounds,
                           final_acc=hist[-1]["acc"])
        if print_csv:
            print(f"fig3_convergence/{method},{wall/rounds*1e6:.0f},"
                  f"rounds_to_{target}={r} final_acc={hist[-1]['acc']:.4f}")
    return out


if __name__ == "__main__":
    main()
