"""Validate the machine-readable BENCH JSON artifacts against their schemas.

The CI bench job generates BENCH_kernels.json / BENCH_round.json on every PR
(quick tiny-shape sweeps) and runs this checker so artifact breakage — a
renamed key, a dropped sweep, a sweep that silently produced no rows — is
caught at PR time instead of by the weekly FULL job's consumers.

    PYTHONPATH=src python -m benchmarks.check_schemas \
        [BENCH_kernels.json] [BENCH_round.json]

Exit code 0 iff both files conform. Schemas are minimal-required: extra keys
are always allowed (sweeps grow), missing ones fail.
"""
from __future__ import annotations

import json
import sys

_KERNEL_KSWEEP_ROW = {
    "K", "sequential_columnwise_us", "sequential_fused_loop_us",
    "batched_engine_us", "batched_fused_us", "peak_live_mb_materialized",
    "peak_live_mb_fused", "ratio_peak_fused_vs_materialized",
}

_MIXER_ROW = {
    "K", "sequential_columnwise_us", "batched_engine_us", "batched_fused_us",
    "ratio_batched_vs_columnwise", "peak_live_mb_materialized",
    "peak_live_mb_fused", "jvp_rel_err",
}

_FULLMODEL_ROW = {
    "K", "standard_us", "fused_us", "ratio_time_fused_vs_standard",
    "peak_live_mb_standard", "peak_live_mb_fused",
    "ratio_peak_fused_vs_standard", "jvp_rel_err",
}

_ROUND_RESULT_ROW = {
    "comm_mode", "executor", "n_devices", "wire", "cohort", "rounds_per_sec",
    "sec_per_round", "bytes_up", "bytes_down",
}

_FAULT_SWEEP_ROW = {
    "fault_rate", "rounds_per_sec", "sec_per_round", "survivor_fraction",
    "quarantined", "lost", "retries",
}

_SERVE_ROW = {
    "arch", "mode", "n_adapters", "max_batch", "fused_prefill", "requests",
    "gen_tokens", "wall_s", "requests_per_sec", "decode_tok_per_sec",
}

_SERVE_SPEEDUP_ROW = {
    "arch", "n_adapters", "fused_prefill", "sequential_rps",
    "continuous_rps", "speedup",
}

# continuous-batching rows additionally carry the adapter-cache traffic of
# the timed run (the paged-LRU behaviour is part of what the bench measures)
_SERVE_CACHE_KEYS = {
    "cache_hits", "cache_misses", "cache_evictions", "cache_hit_rate",
}

_ROOFLINE_ROW = {
    "arch", "shape", "compute_s", "memory_s", "collective_s", "dominant",
    "useful_flop_ratio", "flops_per_device", "collective_bytes_per_device",
    "peak_bytes", "tpu_adjusted_peak_bytes",
}

_ANALYSIS_VMEM_ROW = {
    "kernel", "family", "grid", "block_bytes", "scratch_bytes",
    "residency_bytes", "generation", "budget_bytes", "ok",
}

_ANALYSIS_FINDING = {"rule", "severity", "entrypoint", "where", "message"}


def _require(cond, msg, errors):
    if not cond:
        errors.append(msg)


def _check_rows(rows, required, where, errors):
    _require(isinstance(rows, list) and rows, f"{where}: empty or not a list",
             errors)
    for i, row in enumerate(rows or []):
        missing = required - set(row)
        _require(not missing, f"{where}[{i}]: missing keys {sorted(missing)}",
                 errors)


def check_kernels(doc) -> list:
    errors = []
    for key in ("shapes", "jvp_vs_forward", "fg_ksweep", "fg_mixer_ksweep",
                "fg_fullmodel"):
        _require(key in doc, f"BENCH_kernels: missing top-level {key!r}",
                 errors)
    _check_rows(doc.get("fg_ksweep", []), _KERNEL_KSWEEP_ROW, "fg_ksweep",
                errors)
    mixers = doc.get("fg_mixer_ksweep", {})
    _require(isinstance(mixers, dict) and {"rwkv6", "swa"} <= set(mixers),
             "fg_mixer_ksweep: must cover rwkv6 and swa", errors)
    for mixer, rows in (mixers or {}).items():
        _check_rows(rows, _MIXER_ROW, f"fg_mixer_ksweep[{mixer}]", errors)
    fullmodel = doc.get("fg_fullmodel", {})
    _require(isinstance(fullmodel, dict) and fullmodel,
             "fg_fullmodel: must be a non-empty dict of arch/task sweeps",
             errors)
    for name, rows in (fullmodel or {}).items():
        _check_rows(rows, _FULLMODEL_ROW, f"fg_fullmodel[{name}]", errors)
    return errors


def check_round(doc) -> list:
    errors = []
    _require("round_bench" in doc, "BENCH_round: missing 'round_bench'",
             errors)
    benches = doc.get("round_bench", [])
    _require(isinstance(benches, list) and benches,
             "round_bench: empty or not a list", errors)
    for i, bench in enumerate(benches or []):
        for key in ("arch", "peft_params", "k_perturbations", "results"):
            _require(key in bench, f"round_bench[{i}]: missing {key!r}",
                     errors)
        _check_rows(bench.get("results", []), _ROUND_RESULT_ROW,
                    f"round_bench[{i}].results", errors)
        sweep = bench.get("fault_sweep", [])
        _check_rows(sweep, _FAULT_SWEEP_ROW,
                    f"round_bench[{i}].fault_sweep", errors)
        rates = {row.get("fault_rate") for row in sweep}
        _require(0.0 in rates and any(r > 0 for r in rates if r is not None),
                 f"round_bench[{i}].fault_sweep: needs a clean baseline "
                 f"(rate 0) AND at least one faulty rate", errors)
        for j, row in enumerate(sweep):
            frac = row.get("survivor_fraction")
            _require(isinstance(frac, (int, float)) and 0.0 <= frac <= 1.0,
                     f"round_bench[{i}].fault_sweep[{j}]: "
                     f"survivor_fraction {frac!r} not in [0, 1]", errors)
    return errors


def check_serve(doc) -> list:
    errors = []
    _require("serve_bench" in doc, "BENCH_serve: missing 'serve_bench'",
             errors)
    _check_rows(doc.get("serve_bench", []), _SERVE_ROW, "serve_bench",
                errors)
    modes = {row.get("mode") for row in doc.get("serve_bench", [])}
    _require({"sequential", "continuous"} <= modes,
             "serve_bench: must cover sequential AND continuous modes",
             errors)
    for i, row in enumerate(doc.get("serve_bench", [])):
        if row.get("mode") == "continuous":
            missing = _SERVE_CACHE_KEYS - set(row)
            _require(not missing,
                     f"serve_bench[{i}] (continuous): missing adapter-cache "
                     f"keys {sorted(missing)}", errors)
    _check_rows(doc.get("speedup", []), _SERVE_SPEEDUP_ROW, "speedup",
                errors)
    return errors


def check_roofline(doc) -> list:
    errors = []
    _require("roofline" in doc, "BENCH_roofline: missing 'roofline'", errors)
    rows = doc.get("roofline", [])
    _require(isinstance(rows, list) and rows,
             "roofline: empty or not a list", errors)
    analysed = 0
    for i, row in enumerate(rows or []):
        if row.get("skipped"):
            _require("reason" in row,
                     f"roofline[{i}]: skipped row needs a 'reason'", errors)
            continue
        analysed += 1
        missing = _ROOFLINE_ROW - set(row)
        _require(not missing,
                 f"roofline[{i}]: missing keys {sorted(missing)}", errors)
        _require(row.get("dominant") in ("compute", "memory", "collective"),
                 f"roofline[{i}]: bad dominant {row.get('dominant')!r}",
                 errors)
    _require(analysed > 0, "roofline: every row skipped", errors)
    _require(not doc.get("meta", {}).get("failures"),
             f"roofline: meta.failures non-empty "
             f"({doc.get('meta', {}).get('failures')})", errors)
    return errors


_ASYNC_SYNC_ROW = {
    "deadline_quantile", "utilization", "sim_wall_s",
    "updates_per_sim_hour", "updates_applied", "updates_discarded",
}

_ASYNC_ROW = _ASYNC_SYNC_ROW - {"deadline_quantile"} | {
    "staleness_mean", "staleness_max",
}


def check_async(doc) -> list:
    """BENCH_async: async-vs-sync wall-clock + utilization artifact. The
    useful-compute acceptance bar (>= 1.5x vs the baseline sync quantile at
    10^6 clients) is enforced here so a regression in the staleness/buffer
    policy fails the artifact check, not just a benchmark eyeball."""
    errors = []
    for key in ("schema", "quick", "wall_clock", "utilization"):
        _require(key in doc, f"BENCH_async: missing top-level {key!r}",
                 errors)
    _require(doc.get("schema") == "repro.bench_async/v1",
             f"BENCH_async: unknown schema {doc.get('schema')!r}", errors)
    wall = doc.get("wall_clock", {})
    for key in ("arch", "comm_mode", "sync", "async", "speedup"):
        _require(key in wall, f"wall_clock: missing {key!r}", errors)
    for arm, keys in (("sync", ("rounds", "wall_s", "final_loss")),
                      ("async", ("versions", "wall_s", "final_loss",
                                 "matched"))):
        got = wall.get(arm, {})
        missing = set(keys) - set(got)
        _require(not missing,
                 f"wall_clock.{arm}: missing keys {sorted(missing)}", errors)
    _require(wall.get("async", {}).get("matched") is True,
             "wall_clock: async arm never matched the sync loss", errors)
    _require(isinstance(wall.get("speedup"), (int, float))
             and wall.get("speedup", 0) > 1.0,
             f"wall_clock: async not faster to matched loss "
             f"(speedup={wall.get('speedup')!r})", errors)
    util = doc.get("utilization", {})
    for key in ("n_clients", "sync", "async", "baseline_quantile",
                "utilization_ratio"):
        _require(key in util, f"utilization: missing {key!r}", errors)
    _require(util.get("n_clients", 0) >= 1_000_000,
             f"utilization: scale sim below 10^6 clients "
             f"({util.get('n_clients')!r})", errors)
    _check_rows(util.get("sync", []), _ASYNC_SYNC_ROW, "utilization.sync",
                errors)
    quants = {r.get("deadline_quantile") for r in util.get("sync", [])}
    _require(util.get("baseline_quantile") in quants,
             f"utilization: baseline_quantile "
             f"{util.get('baseline_quantile')!r} has no sync row", errors)
    arow = util.get("async", {})
    missing = _ASYNC_ROW - set(arow)
    _require(not missing,
             f"utilization.async: missing keys {sorted(missing)}", errors)
    ratio = util.get("utilization_ratio")
    _require(isinstance(ratio, (int, float)) and ratio >= 1.5,
             f"utilization: useful-compute ratio {ratio!r} below the "
             f"1.5x acceptance bar", errors)
    return errors


# telemetry JSONL run artifacts (repro.obs) — validated by the CI telemetry
# smoke step rather than tracked in-repo
_TELEMETRY_REQUIRED = {"ts", "kind", "run_id"}


def check_telemetry_jsonl(path, expect_kinds=()) -> list:
    """Validate a telemetry JSONL event log: every line parses, every event
    carries the envelope keys, and ``expect_kinds`` all occur."""
    errors = []
    kinds = set()
    try:
        with open(path) as f:
            lines = [ln for ln in f if ln.strip()]
    except OSError as e:
        return [f"{path}: unreadable ({e})"]
    _require(lines, f"{path}: empty event log", errors)
    for i, line in enumerate(lines):
        try:
            ev = json.loads(line)
        except json.JSONDecodeError as e:
            errors.append(f"{path}:{i + 1}: bad JSONL ({e})")
            continue
        missing = _TELEMETRY_REQUIRED - set(ev)
        _require(not missing,
                 f"{path}:{i + 1}: missing envelope keys {sorted(missing)}",
                 errors)
        kinds.add(ev.get("kind"))
    for kind in expect_kinds:
        _require(kind in kinds,
                 f"{path}: no {kind!r} events (saw {sorted(kinds)})", errors)
    return errors


def check_analysis(doc) -> list:
    """The repro.analysis lint artifact: per-kernel VMEM residency table +
    findings audit trail (tracked ANALYSIS.json)."""
    errors = []
    for key in ("schema", "rules", "budget", "entrypoints", "vmem_kernels",
                "findings", "summary"):
        _require(key in doc, f"ANALYSIS: missing top-level {key!r}", errors)
    _require(doc.get("schema") == "repro.analysis/v1",
             f"ANALYSIS: unknown schema {doc.get('schema')!r}", errors)
    _require(len(doc.get("rules", [])) >= 5,
             "ANALYSIS: fewer than 5 rule classes", errors)
    budget = doc.get("budget", {})
    _require(isinstance(budget.get("vmem_bytes_per_core"), int)
             and budget.get("vmem_bytes_per_core", 0) > 0,
             "ANALYSIS: budget.vmem_bytes_per_core must be a positive int",
             errors)
    _check_rows(doc.get("vmem_kernels", []), _ANALYSIS_VMEM_ROW,
                "vmem_kernels", errors)
    families = {r.get("family") for r in doc.get("vmem_kernels", [])}
    _require({"lora_dual", "wkv6_scan", "swa_attention",
              "mamba2_scan"} <= families,
             "ANALYSIS: vmem_kernels must cover all four kernel families",
             errors)
    for i, f in enumerate(doc.get("findings", [])):
        missing = _ANALYSIS_FINDING - set(f)
        _require(not missing, f"findings[{i}]: missing keys "
                              f"{sorted(missing)}", errors)
    _require(isinstance(doc.get("entrypoints"), list)
             and doc.get("entrypoints"),
             "ANALYSIS: entrypoints empty or not a list", errors)
    summary = doc.get("summary", {})
    for key in ("errors", "warnings", "info"):
        _require(isinstance(summary.get(key), int),
                 f"ANALYSIS: summary.{key} must be an int", errors)
    return errors


def main(kernels_path="BENCH_kernels.json", round_path="BENCH_round.json",
         serve_path="BENCH_serve.json", analysis_path="ANALYSIS.json",
         roofline_path="BENCH_roofline.json",
         async_path="BENCH_async.json"):
    errors = []
    paths = (kernels_path, round_path, serve_path, analysis_path,
             roofline_path, async_path)
    for path, check in ((kernels_path, check_kernels),
                        (round_path, check_round),
                        (serve_path, check_serve),
                        (analysis_path, check_analysis),
                        (roofline_path, check_roofline),
                        (async_path, check_async)):
        try:
            errors += check(json.load(open(path)))
        except (OSError, json.JSONDecodeError) as e:
            errors.append(f"{path}: unreadable ({e})")
    for err in errors:
        print(f"SCHEMA ERROR: {err}")
    if not errors:
        print(f"ok: {', '.join(paths)} conform")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(*sys.argv[1:]))
