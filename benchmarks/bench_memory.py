"""Paper Figure 2 — peak training memory: backprop vs zero-order vs
Forward-mode AD (SPRY).

Measured structurally via ``compiled.memory_analysis()`` of the three
client-update programs on ONE device (no allocation): the temp size is the
activation/residual footprint the paper's figure attributes the savings to.
Models: the paper's own RoBERTa-Large (355M) and Llama2-7B, batch 8,
seq 128 (paper Appendix B).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import SpryConfig, get_config
from repro.core.forward_grad import forward_gradient
from repro.models.registry import lm_loss
from repro.models import get_model
from repro.peft import init_peft
from repro.utils.pytree import normal_like


def client_programs(cfg, batch_size=8, seq=128):
    sc = SpryConfig()
    model = get_model(cfg)

    def init():
        key = jax.random.PRNGKey(0)
        return model.init_base(cfg, key), init_peft(cfg, key, sc)

    base, peft = jax.eval_shape(init)
    batch = {"tokens": jax.ShapeDtypeStruct((batch_size, seq), jnp.int32)}

    def backprop_step(base, peft, batch):
        g = jax.grad(lambda p: lm_loss(cfg, base, p, batch))(peft)
        return jax.tree.map(lambda p, gi: p - 1e-3 * gi, peft, g)

    def spry_step(base, peft, batch, key):
        loss, g, _ = forward_gradient(
            lambda p: lm_loss(cfg, base, p, batch), peft, key)
        return jax.tree.map(lambda p, gi: p - 1e-3 * gi, peft, g)

    def zo_step(base, peft, batch, key):
        v = normal_like(key, peft, dtype=jnp.float32)
        eps = 1e-3
        lp = lm_loss(cfg, base, jax.tree.map(lambda p, vi: p + eps * vi, peft, v), batch)
        lm = lm_loss(cfg, base, jax.tree.map(lambda p, vi: p - eps * vi, peft, v), batch)
        fd = (lp - lm) / (2 * eps)
        return jax.tree.map(lambda p, vi: p - 1e-3 * fd * vi, peft, v)

    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return {
        "backprop": (backprop_step, (base, peft, batch)),
        "spry_forward_ad": (spry_step, (base, peft, batch, key)),
        "zero_order": (zo_step, (base, peft, batch, key)),
    }


def run(arch="roberta-large-lora", batch_size=8, seq=128):
    cfg = get_config(arch)
    rows = []
    for name, (fn, args) in client_programs(cfg, batch_size, seq).items():
        t0 = time.time()
        compiled = jax.jit(fn).lower(*args).compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        temp = getattr(mem, "temp_size_in_bytes", 0) or 0
        arg = getattr(mem, "argument_size_in_bytes", 0) or 0
        rows.append({
            "method": name,
            "arch": arch,
            "temp_bytes": temp,
            "arg_bytes": arg,
            "peak_bytes": temp + arg,
            "flops": float(cost.get("flops", 0.0)),
            "compile_s": time.time() - t0,
        })
    return rows


def main(print_csv=True, archs=("roberta-large-lora", "llama2-7b")):
    out = []
    for arch in archs:
        rows = run(arch)
        bp = next(r for r in rows if r["method"] == "backprop")
        for r in rows:
            ratio = bp["temp_bytes"] / max(r["temp_bytes"], 1)
            derived = (f"temp={r['temp_bytes']/1e9:.3f}GB peak={r['peak_bytes']/1e9:.3f}GB "
                       f"flops={r['flops']:.3e} bp_temp_ratio={ratio:.2f}x")
            if print_csv:
                print(f"fig2_memory/{arch}/{r['method']},{r['compile_s']*1e6:.0f},{derived}")
            out.append({**r, "bp_temp_ratio": ratio})
    return out


if __name__ == "__main__":
    main()
