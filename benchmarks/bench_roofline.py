"""Roofline artifact — per-(arch x shape) compute/memory/collective terms
from the dry-run lowering analysis, written machine-readably to
BENCH_roofline.json (tracked, schema-checked by benchmarks.check_schemas).

Missing dry-run artifacts are generated in place (each case lowers +
compiles the sharded step on the host mesh, a few seconds per case on CPU),
so the bench is self-contained:

    PYTHONPATH=src JAX_PLATFORMS=cpu \
        XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m benchmarks.bench_roofline [--quick]
"""
from __future__ import annotations

import argparse
import json
import os

# CI smoke subset: one attention family + one recurrent family, the
# training shape and the decode shape
QUICK_CASES = [
    ("gemma3-12b", "train_4k"),
    ("gemma3-12b", "decode_32k"),
    ("rwkv6-1.6b", "train_4k"),
    ("rwkv6-1.6b", "decode_32k"),
]


def full_cases():
    from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES
    return [(a, s) for a in ASSIGNED_ARCHS for s in INPUT_SHAPES]


def load_or_run(arch, shape, out_dir, pod="pod1"):
    path = os.path.join(out_dir, f"{arch}__{shape}__{pod}.json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    from repro.launch.dryrun import run_case
    return run_case(arch, shape, multi_pod=(pod == "pod2"), out_dir=out_dir)


def roofline_row(rec):
    if rec.get("skipped"):
        return {"arch": rec["arch"], "shape": rec["shape"], "skipped": True,
                "reason": rec.get("reason", "")}
    rf = rec["roofline"]
    pd = rec["per_device"]
    mem = rec["memory_analysis"]
    return {
        "arch": rec["arch"], "shape": rec["shape"], "skipped": False,
        "mesh": rec.get("mesh"),
        "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
        "collective_s": rf["collective_s"],
        # dryrun names the dominant term by its field ("memory_s") —
        # normalize to the plain roofline regime name
        "dominant": rf["dominant"].replace("_s", ""),
        "useful_flop_ratio": rf["useful_flop_ratio"],
        "flops_per_device": pd["flops"],
        "collective_bytes_per_device": pd["collective_bytes_total"],
        "peak_bytes": mem["peak_bytes"],
        "tpu_adjusted_peak_bytes": mem["tpu_adjusted_peak"],
    }


def main(quick=False, out="BENCH_roofline.json",
         dryrun_dir="experiments/dryrun"):
    cases = QUICK_CASES if quick else full_cases()
    rows, failures = [], []
    for arch, shape in cases:
        try:
            rows.append(roofline_row(load_or_run(arch, shape, dryrun_dir)))
        except Exception as e:  # noqa: BLE001 - record and continue
            failures.append({"arch": arch, "shape": shape, "error": repr(e)})
            print(f"[roofline] FAIL {arch} x {shape}: {e}")

    for r in rows:
        if r.get("skipped"):
            print(f"roofline/{r['arch']}/{r['shape']},0,"
                  f"SKIPPED({r['reason'][:40]})")
        else:
            print(f"roofline/{r['arch']}/{r['shape']},0,"
                  f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
                  f"collective={r['collective_s']:.4f}s "
                  f"dominant={r['dominant']} "
                  f"useful={r['useful_flop_ratio']:.3f} "
                  f"peakGB={r['peak_bytes'] / 1e9:.2f}")

    doc = {
        "meta": {"quick": quick, "pod": "pod1",
                 "cases": len(cases), "failures": failures},
        "roofline": rows,
    }
    with open(out, "w") as f:
        json.dump(doc, f, indent=1)
    n_ok = sum(1 for r in rows if not r.get("skipped"))
    print(f"wrote {out}: {n_ok} analysed, "
          f"{len(rows) - n_ok} skipped, {len(failures)} failed")
    return 1 if failures else 0


def cli():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 2 archs x 2 shapes")
    ap.add_argument("--out", default="BENCH_roofline.json")
    ap.add_argument("--dryrun-dir", default="experiments/dryrun",
                    help="dry-run artifact cache (generated when missing)")
    args = ap.parse_args()
    return main(quick=args.quick, out=args.out, dryrun_dir=args.dryrun_dir)


if __name__ == "__main__":
    import sys
    sys.exit(cli())

