"""Roofline summary table — reads the dry-run artifacts
(experiments/dryrun/*.json) and prints the per-(arch x shape) terms.
Run the dry-run first:
    PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
"""
from __future__ import annotations

import glob
import json
import os


def load(out_dir="experiments/dryrun", pod="pod1"):
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, f"*__{pod}.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def main(print_csv=True, out_dir="experiments/dryrun"):
    rows = load(out_dir)
    if not rows:
        print("roofline/no_dryrun_artifacts,0,run repro.launch.dryrun first")
        return []
    for r in rows:
        if r.get("skipped"):
            if print_csv:
                print(f"roofline/{r['arch']}/{r['shape']},0,SKIPPED({r['reason'][:40]})")
            continue
        rf = r["roofline"]
        pd = r["per_device"]
        mem = r["memory_analysis"]
        if print_csv:
            print(f"roofline/{r['arch']}/{r['shape']},0,"
                  f"compute={rf['compute_s']:.4f}s memory={rf['memory_s']:.4f}s "
                  f"collective={rf['collective_s']:.4f}s dominant={rf['dominant']} "
                  f"useful={rf['useful_flop_ratio']:.3f} "
                  f"peakGB={mem['peak_bytes']/1e9:.2f}")
    return rows


if __name__ == "__main__":
    main()
