"""Paper Table 1 — generalized accuracy: SPRY vs backprop (FedAvg/FedYogi)
vs zero-order (FedMeZO/BAFFLE+/FwdLLM+) on Dirichlet-heterogeneous synthetic
tasks (alpha=0.1), reduced RoBERTa-Large, fixed round budget.
"""
from __future__ import annotations

import time

import jax

from repro.launch.train import run_training

METHODS = ("fedavg", "fedyogi", "fwdllm", "fedmezo", "baffle", "spry")


def main(print_csv=True, rounds=40, tasks=("sst2", "agnews")):
    results = {}
    for task in tasks:
        for method in METHODS:
            t0 = time.time()
            extra = {}
            if method == "spry":
                # paper knobs: K>1 speeds convergence (Fig 5a); jvp clipping
                # is our beyond-paper stabiliser (EXPERIMENTS)
                extra = dict(k_perturbations=4, jvp_clip=10.0,
                             clients_per_round=8)
            hist = run_training(
                arch="roberta-large-lora", task=task, method=method,
                rounds=rounds, total_clients=16,
                batch_size=8, dirichlet_alpha=0.1, eval_every=rounds,
                seed=0, local_lr=1e-2, server_lr=2e-2,
                log=lambda *a: None,
                **{"clients_per_round": 4, **extra})
            jax.clear_caches()
            acc = hist[-1]["acc"]
            dt = time.time() - t0
            results[(task, method)] = acc
            if print_csv:
                print(f"table1_accuracy/{task}/{method},"
                      f"{dt/rounds*1e6:.0f},acc={acc:.4f} rounds={rounds}")
    return results


if __name__ == "__main__":
    main()
