"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.render_roofline_md > experiments/roofline.md
"""
from __future__ import annotations

import glob
import json
import os


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/1e9:.2f}"


def load(pod):
    rows = {}
    for path in sorted(glob.glob(f"experiments/dryrun/*__{pod}.json")):
        with open(path) as f:
            d = json.load(f)
        rows[(d["arch"], d["shape"])] = d
    return rows


SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main():
    pod1 = load("pod1")
    pod2 = load("pod2")
    archs = sorted({a for a, _ in pod1})

    print("### Dry-run (single-pod 16x16 = 256 chips; multi-pod 2x16x16 = 512"
          " chips)\n")
    print("| arch | shape | pod1 peak GB/dev | tpu-adjusted GB | pod1 coll"
          " GB/dev (tpu-adj) | pod2 ok | compile s |")
    print("|---|---|---|---|---|---|---|")
    for a in archs:
        for s in SHAPES:
            d = pod1.get((a, s))
            if d is None:
                continue
            d2 = pod2.get((a, s))
            if d.get("skipped"):
                print(f"| {a} | {s} | SKIP (full attention) | - | - | "
                      f"{'SKIP' if d2 and d2.get('skipped') else '?'} | - |")
                continue
            mem = d["memory_analysis"]
            adj = mem.get("tpu_adjusted_peak")
            coll_adj = d["per_device"].get("collective_bytes_tpu_adj",
                                           d["per_device"]["collective_bytes_total"])
            print(f"| {a} | {s} | {fmt_bytes(mem['peak_bytes'])} | "
                  f"{fmt_bytes(adj)} | "
                  f"{coll_adj/1e9:.2f} | "
                  f"{'yes' if d2 and not d2.get('skipped') else 'MISSING'} | "
                  f"{d.get('t_compile_s', 0):.1f} |")

    print("\n### Roofline (single-pod, v5e: 197 bf16 TF/s, 819 GB/s HBM, "
          "50 GB/s/link)\n")
    print("| arch | shape | compute s | memory s | collective s | dominant |"
          " MODEL_FLOPS/chip | useful ratio |")
    print("|---|---|---|---|---|---|---|---|")
    for a in archs:
        for s in SHAPES:
            d = pod1.get((a, s))
            if d is None or d.get("skipped"):
                continue
            r = d["roofline"]
            print(f"| {a} | {s} | {r['compute_s']:.4f} | {r['memory_s']:.4f} |"
                  f" {r['collective_s']:.4f} | {r['dominant'].replace('_s','')} |"
                  f" {r['model_flops_per_chip']:.3e} |"
                  f" {r['useful_flop_ratio']:.3f} |")


if __name__ == "__main__":
    main()
