"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--quick]

Output format: ``name,us_per_call,derived`` CSV lines. The kernel suite
additionally writes ``BENCH_kernels.json`` (machine-readable K-sweep +
acceptance ratios) so the perf trajectory is recorded across PRs.

  table1_accuracy   paper Table 1  — SPRY vs backprop vs zero-order accuracy
  fig2_memory       paper Figure 2 — peak training memory (compiled analysis)
  fig3_convergence  paper Figure 3 — rounds/time to convergence
  table2_3_costs    paper Tables 2-3 — comm/compute accounting
  fig5_ablation     paper Figs 4-5 — splitting/K/client-count ablations
  kernel            §5.3 — fused jvp vs separate forwards + kernel oracle
  roofline          EXPERIMENTS §Roofline — reads dry-run artifacts
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    bench_ablations,
    bench_accuracy,
    bench_convergence,
    bench_costs,
    bench_kernels,
    bench_memory,
    bench_roofline,
)

SUITES = {
    "table2_3_costs": lambda quick: bench_costs.main(),
    # kernel suite also records the perf trajectory machine-readably
    "kernel": lambda quick: bench_kernels.main(
        quick=quick, json_path="BENCH_kernels.json"),
    "fig2_memory": lambda quick: bench_memory.main(
        archs=("roberta-large-lora",) if quick
        else ("roberta-large-lora", "llama2-7b")),
    "roofline": lambda quick: bench_roofline.main(quick=quick),
    "fig3_convergence": lambda quick: bench_convergence.main(
        rounds=20 if quick else 50),
    "fig5_ablation": lambda quick: bench_ablations.main(),
    "table1_accuracy": lambda quick: bench_accuracy.main(
        rounds=20 if quick else 40,
        tasks=("sst2",)),   # agnews via bench_accuracy.main(tasks=...)
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    names = [args.only] if args.only else list(SUITES)
    failures = []
    for name in names:
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            SUITES[name](args.quick)
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
        print(f"# {name} done in {time.time()-t0:.0f}s", flush=True)
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)
    print("# all benchmark suites completed")


if __name__ == "__main__":
    main()
