"""Federation-runtime round benchmark (ISSUE 3).

Measures rounds/sec and bytes-on-wire vs cohort size across the runtime's
execution strategies, and records the aggregation-memory story that
motivates the streaming executor:

  executor      serial-1dev (whole-cohort vmap, stacked aggregation) vs
                sharded-8dev (shard_map + scan streaming aggregation)
  comm mode     per_epoch (masked-delta uplink) vs per_iteration (K jvp
                scalars + seed ref)
  wire dtype    fp32 vs bf16 scalar quantization (measured frame bytes)
  cohort size   sweep past the in-process M — the stacked (C, |peft|)
                aggregation grows linearly while the streaming accumulator
                stays O(|peft|) per device (agg_bytes_* fields)
  fault rate    chaos sweep (0 / 5 / 20%% crash+corrupt+loss): rounds/sec
                and the effective-survivor fraction the quarantine +
                validation stack leaves for aggregation

Results append machine-readably to BENCH_round.json:

    PYTHONPATH=src JAX_PLATFORMS=cpu \
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m benchmarks.bench_round [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SpryConfig, get_config, reduce_config
from repro.core import enumerate_units, init_state
from repro.fl.runtime import (
    ClientPopulation,
    CohortScheduler,
    FaultConfig,
    FederationEngine,
    SerialExecutor,
    ShardedExecutor,
    WireConfig,
)
from repro.models import get_model
from repro.peft import init_peft
from repro.utils.pytree import tree_size

ARCH = "roberta-large-lora"
B, S = 2, 16


def _setup(seed=0):
    cfg = reduce_config(get_config(ARCH))
    sc = SpryConfig(n_clients_per_round=8, local_iters=1, local_lr=1e-2,
                    server_lr=1e-2, k_perturbations=2)
    key = jax.random.PRNGKey(seed)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, sc)
    state = init_state(base, peft)
    rng = np.random.default_rng(seed)
    x = rng.integers(0, cfg.vocab, size=(4096, S), dtype=np.int64)
    y = rng.integers(0, cfg.n_classes, size=(4096,), dtype=np.int64)
    return cfg, sc, state, x, y


def _time_rounds(engine, scheduler, state, n_units, sc, cohort, reps):
    """Wall-time `reps` scheduled rounds (after a warmup compile round)."""
    plans, batches = [], []
    for r in range(reps + 1):
        plan = scheduler.plan_round(r, n_units, sc.seed,
                                    client_ids=np.arange(cohort))
        bx, by = scheduler.round_batch(plan, B)
        plans.append(plan)
        batches.append({"tokens": jnp.asarray(bx), "labels": jnp.asarray(by)})
    # warmup (compile)
    st, _, report = engine.run_round(state, plans[0], batches[0])
    jax.block_until_ready(jax.tree.leaves(st.peft))
    t0 = time.perf_counter()
    for r in range(1, reps + 1):
        st, _, report = engine.run_round(st, plans[r], batches[r])
    jax.block_until_ready(jax.tree.leaves(st.peft))
    dt = (time.perf_counter() - t0) / reps
    return dt, report


def _fault_sweep(cfg, sc, state, pop, n_units, reps):
    """Chaos overhead: rounds/sec + effective-survivor fraction as the
    fault rate climbs (rate applied to crash, corrupt, and loss alike).
    Rate 0 runs the clean simulated wire — the chaos path's baseline."""
    rows = []
    C = 8
    for rate in (0.0, 0.05, 0.2):
        scheduler = CohortScheduler(pop, cohort_size=C, over_select=1.0,
                                    deadline=float("inf"), seed=0)
        faults = (FaultConfig(crash_rate=rate, corrupt_rate=rate,
                              loss_rate=rate, seed=0) if rate > 0 else None)
        engine = FederationEngine(
            cfg, sc, comm_mode="per_epoch", executor=SerialExecutor(),
            wire=WireConfig(simulate=True), faults=faults)
        plans, batches = [], []
        for r in range(reps + 1):
            plan = scheduler.plan_round(r, n_units, sc.seed,
                                        client_ids=np.arange(C))
            bx, by = scheduler.round_batch(plan, B)
            plans.append(plan)
            batches.append({"tokens": jnp.asarray(bx),
                            "labels": jnp.asarray(by)})
        st, _, _ = engine.run_round(state, plans[0], batches[0])  # warmup
        jax.block_until_ready(jax.tree.leaves(st.peft))
        fracs, t0 = [], time.perf_counter()
        for r in range(1, reps + 1):
            st, _, report = engine.run_round(st, plans[r], batches[r])
            fracs.append(report.n_validated / report.cohort_size)
        jax.block_until_ready(jax.tree.leaves(st.peft))
        dt = (time.perf_counter() - t0) / reps
        h = report.health
        row = {
            "fault_rate": rate,
            "rounds_per_sec": 1.0 / dt,
            "sec_per_round": dt,
            "survivor_fraction": float(np.mean(fracs)),
            "bytes_up": report.bytes_up,
            "quarantined": 0 if h is None else h.quarantined,
            "lost": 0 if h is None else h.lost,
            "retries": 0 if h is None else h.retries,
        }
        rows.append(row)
        print(f"[bench_round] fault_sweep rate={rate:4.2f} "
              f"{1.0/dt:6.2f} rounds/s  "
              f"survivors={row['survivor_fraction']:.2f}  "
              f"quarantined={row['quarantined']} lost={row['lost']}")
    return rows


def main(quick: bool = False, json_path: str = "BENCH_round.json"):
    cfg, sc, state, x, y = _setup()
    n_units = enumerate_units(state.peft).n_units
    peft_params = tree_size(state.peft)
    n_dev = len(jax.devices())
    reps = 2 if quick else 3
    cohorts = (8, 16) if quick else (8, 16, 32)

    pop = ClientPopulation(x, y, n_clients=1_000_000, alpha=0.1, seed=0,
                           shard_size=32)

    results = []
    for comm_mode in ("per_epoch", "per_iteration"):
        for label, make_exec, devs in (
                ("serial_1dev", lambda: SerialExecutor(), 1),
                ("sharded_8dev", lambda: ShardedExecutor(microbatch=1),
                 n_dev)):
            for wire in ("fp32", "bf16"):
                for C in cohorts:
                    scheduler = CohortScheduler(pop, cohort_size=C,
                                                over_select=1.0,
                                                deadline=float("inf"),
                                                seed=0)
                    engine = FederationEngine(
                        cfg, sc, comm_mode=comm_mode, executor=make_exec(),
                        wire=WireConfig(dtype=wire, simulate=False))
                    dt, report = _time_rounds(engine, scheduler, state,
                                              n_units, sc, C, reps)
                    row = {
                        "comm_mode": comm_mode,
                        "executor": label,
                        "n_devices": devs,
                        "wire": wire,
                        "cohort": C,
                        "rounds_per_sec": 1.0 / dt,
                        "sec_per_round": dt,
                        "bytes_up": report.bytes_up,
                        "bytes_down": report.bytes_down,
                        "agg_bytes_streaming": report.agg_bytes_streaming,
                        "agg_bytes_stacked": report.agg_bytes_stacked,
                    }
                    results.append(row)
                    print(f"[bench_round] {comm_mode:13s} {label:12s} "
                          f"wire={wire} C={C:3d} "
                          f"{1.0/dt:6.2f} rounds/s  "
                          f"up={report.bytes_up/1e3:8.1f}kB  "
                          f"agg_stream={report.agg_bytes_streaming/1e3:.1f}kB"
                          f" vs stacked={report.agg_bytes_stacked/1e3:.1f}kB")

    # headline checks recorded machine-readably: streaming aggregation memory
    # is flat in cohort size; the stacked equivalent grows linearly
    stream = [r for r in results if r["executor"] == "sharded_8dev"]
    by_cohort = {}
    for r in stream:
        by_cohort.setdefault(r["cohort"], r["agg_bytes_streaming"])
    flat = len(set(by_cohort.values())) == 1
    fault_rows = _fault_sweep(cfg, sc, state, pop, n_units, reps)
    doc = {
        "arch": ARCH,
        "peft_params": int(peft_params),
        "peft_bytes_fp32": int(peft_params * 4),
        "batch_shape": [B, S],
        "k_perturbations": sc.k_perturbations,
        "n_devices": n_dev,
        "streaming_agg_flat_in_cohort": bool(flat),
        "results": results,
        "fault_sweep": fault_rows,
    }
    out = {}
    if os.path.exists(json_path):
        with open(json_path) as f:
            out = json.load(f)
    out.setdefault("round_bench", []).append(doc)
    with open(json_path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"[bench_round] wrote {json_path} "
          f"(streaming agg flat in cohort: {flat})")
    return doc


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default="BENCH_round.json")
    args = ap.parse_args()
    main(quick=args.quick, json_path=args.json)
