"""Multi-tenant serving benchmark (paged adapter cache + continuous batching).

Measures requests/sec over a population of per-client LoRA adapters, sweeping
adapters-resident and prefill mode across two serving strategies:

  sequential    one-adapter-at-a-time baseline: each request runs
                ``greedy_generate`` alone at B=1 with its own peft tree
                (adapter trees preloaded OUTSIDE the timed region — the
                baseline is charged for serialization, not adapter loading)
  continuous    ``ServingEngine``: requests admitted into the in-flight
                batch, every decode step advances up to max_batch requests
                through ONE batched multi-adapter step

Both strategies produce identical ids (asserted per sweep). Compile time is
excluded: each engine / fns set is warmed on a throwaway workload first.

Results write machine-readably to BENCH_serve.json:

    PYTHONPATH=src JAX_PLATFORMS=cpu python -m benchmarks.bench_serve [--quick]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.launch.adapter_cache import AdapterCache, SyntheticAdapterStore
from repro.launch.serve import build_serve_fns, greedy_generate
from repro.launch.serving import Request, ServingEngine
from repro.models import get_model

P_PROMPT = 6


def _requests(cfg, n_requests, n_adapters, n_new, tag=""):
    key = jax.random.PRNGKey(7)
    reqs = []
    for i in range(n_requests):
        prompt = np.asarray(
            jax.random.randint(jax.random.fold_in(key, i), (P_PROMPT,), 0,
                               cfg.vocab), np.int32)
        reqs.append(Request(request_id=f"{tag}r{i}", adapter_id=i % n_adapters,
                            prompt=prompt, max_new_tokens=n_new))
    return reqs


def _run_sequential(cfg, base, fns, trees, reqs, n_new, fused):
    out = {}
    for req in reqs:
        ids = greedy_generate(cfg, base, trees[req.adapter_id],
                              np.asarray(req.prompt)[None], n_new,
                              cache_len=P_PROMPT + n_new, fns=fns,
                              fused_prefill=fused)
        out[req.request_id] = list(np.asarray(ids[0]))
    return out


def bench_arch(arch, adapter_counts, n_new, max_batch, quick):
    cfg = reduce_config(get_config(arch))
    model = get_model(cfg)
    base = model.init_base(cfg, jax.random.PRNGKey(0))
    store = SyntheticAdapterStore(cfg)
    fns = build_serve_fns(cfg, model)
    rows, speedups = [], []

    for n_adapters in adapter_counts:
        n_requests = 2 * n_adapters
        trees = {a: store.load(a) for a in range(n_adapters)}
        reqs = _requests(cfg, n_requests, n_adapters, n_new)
        warm = _requests(cfg, max_batch, n_adapters, n_new, tag="warm_")
        rps = {}
        for fused in (True, False):
            # sequential baseline (warm once per prefill mode)
            _run_sequential(cfg, base, fns, trees, warm[:1], n_new, fused)
            t0 = time.time()
            seq_out = _run_sequential(cfg, base, fns, trees, reqs, n_new,
                                      fused)
            seq_wall = time.time() - t0

            # continuous batching engine (same engine for warmup + timed so
            # the timed run hits the already-compiled batched step)
            ac = AdapterCache(store, capacity=n_adapters)
            eng = ServingEngine(cfg, base, ac, max_batch=max_batch,
                                cache_len=P_PROMPT + n_new,
                                fused_prefill=fused)
            eng.run(warm)
            cs0 = ac.stats()
            t0 = time.time()
            eng_out = eng.run(reqs)
            eng_wall = time.time() - t0
            cs1 = ac.stats()
            # cache traffic of the TIMED run only (warmup excluded)
            c_hits = cs1["hits"] - cs0["hits"]
            c_miss = cs1["misses"] - cs0["misses"]

            for rid, ids in seq_out.items():
                assert eng_out[rid] == ids, (arch, n_adapters, fused, rid)
            gen = n_requests * n_new
            for mode, wall in (("sequential", seq_wall),
                               ("continuous", eng_wall)):
                row = {
                    "arch": arch, "mode": mode, "n_adapters": n_adapters,
                    "max_batch": max_batch, "fused_prefill": fused,
                    "requests": n_requests, "gen_tokens": gen,
                    "wall_s": round(wall, 4),
                    "requests_per_sec": round(n_requests / wall, 3),
                    "decode_tok_per_sec": round(gen / wall, 2),
                }
                if mode == "continuous":
                    row.update({
                        "cache_hits": c_hits,
                        "cache_misses": c_miss,
                        "cache_evictions": cs1["evictions"]
                        - cs0["evictions"],
                        "cache_hit_rate": round(
                            c_hits / max(1, c_hits + c_miss), 4),
                    })
                rows.append(row)
            rps[("seq", fused)] = n_requests / seq_wall
            rps[("eng", fused)] = n_requests / eng_wall
            print(f"[serve] {arch} adapters={n_adapters} fused={fused}: "
                  f"sequential {rps[('seq', fused)]:.2f} req/s, "
                  f"continuous {rps[('eng', fused)]:.2f} req/s "
                  f"({rps[('eng', fused)] / rps[('seq', fused)]:.2f}x)")
        speedups.append({
            "arch": arch, "n_adapters": n_adapters, "fused_prefill": True,
            "sequential_rps": round(rps[("seq", True)], 3),
            "continuous_rps": round(rps[("eng", True)], 3),
            "speedup": round(rps[("eng", True)] / rps[("seq", True)], 3),
        })
    return rows, speedups


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: one arch, short generations")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()

    if args.quick:
        archs, adapter_counts, n_new, max_batch = (
            ["llama2-7b"], [2, 8], 8, 8)
    else:
        archs, adapter_counts, n_new, max_batch = (
            ["llama2-7b", "rwkv6-1.6b"], [2, 4, 8, 12], 24, 8)

    rows, speedups = [], []
    for arch in archs:
        r, s = bench_arch(arch, adapter_counts, n_new, max_batch, args.quick)
        rows += r
        speedups += s

    doc = {
        "meta": {"quick": args.quick, "prompt_len": P_PROMPT,
                 "new_tokens": n_new, "max_batch": max_batch,
                 "ids_checked": True},
        "serve_bench": rows,
        "speedup": speedups,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    best = max(s["speedup"] for s in speedups if s["n_adapters"] >= 8)
    print(f"wrote {args.out}; continuous-vs-sequential speedup at >=8 "
          f"adapters: {best:.2f}x")


if __name__ == "__main__":
    main()
