"""Async (FedBuff-style) vs synchronous federation benchmark (ISSUE 10).

Two measurements, appended machine-readably to BENCH_async.json:

  wall_clock    toy finetuning run on the reduced arch: a synchronous
                engine that waits for every cohort member (virtual wall
                clock = per-round max of the population's two-part
                compute + uplink latency model) vs the async engine's
                event-driven clock, run until it matches the sync run's
                final smoothed loss. Reports simulated-wall speedup to
                matched loss.

  utilization   useful-compute fraction at 10^6 logical clients via the
                deterministic event simulators (events.py): sync rounds
                cut stragglers at a deadline quantile — their compute is
                wasted — while async folds every arrival into a later
                buffer. Sync is swept over deadline quantiles {0.5, 0.75,
                0.9}; the headline ratio compares against q0.75 (the
                throughput-comparable operating point). The q0.9 row is
                reported too: it narrows the utilization gap only by
                inflating sync wall-clock ~1.7x (see updates_per_sim_hour),
                which the wall_clock section prices honestly.

    PYTHONPATH=src JAX_PLATFORMS=cpu \
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m benchmarks.bench_async [--quick]
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro.configs import SpryConfig, get_config, reduce_config
from repro.core import enumerate_units, init_state
from repro.fl.runtime import (
    AsyncConfig,
    AsyncFederationEngine,
    ClientPopulation,
    CohortScheduler,
    FederationEngine,
    WireConfig,
    simulate_async_utilization,
    simulate_sync_utilization,
)
from repro.models import get_model
from repro.peft import init_peft

ARCH = "roberta-large-lora"
B, S = 2, 16
WORK_S = 60.0
SCALE_CLIENTS = 1_000_000
SYNC_QUANTILES = (0.5, 0.75, 0.9)
BASELINE_QUANTILE = 0.75


def _toy_data(cfg, n=512, seed=0):
    """Learnable synthetic task (label = function of tokens) — matched-loss
    comparisons are meaningless on random labels, where training can only
    degrade held-out loss."""
    rng = np.random.default_rng(seed)
    x = rng.integers(0, cfg.vocab, size=(n, S), dtype=np.int64)
    y = (x.sum(axis=1) % cfg.n_classes).astype(np.int64)
    return x, y


def _setup(seed=0):
    cfg = reduce_config(get_config(ARCH))
    # server_lr tuned so the toy task actually learns under forward-gradient
    # noise (at 1e-2 BOTH arms drift away from init and matched-loss
    # comparisons are meaningless)
    sc = SpryConfig(n_clients_per_round=8, local_iters=1, local_lr=1e-2,
                    server_lr=1e-3, k_perturbations=2)
    key = jax.random.PRNGKey(seed)
    model = get_model(cfg)
    base = model.init_base(cfg, key)
    peft = init_peft(cfg, key, sc)
    return cfg, sc, init_state(base, peft)


def bench_wall_clock(quick: bool) -> dict:
    """Simulated wall seconds to matched held-out loss, sync vs async.
    Both arms train on the same non-iid population and are scored on one
    FIXED eval batch (per-cohort training loss is too noisy to match on)."""
    import jax.numpy as jnp
    from repro.models import cls_logits

    cfg, sc, state = _setup()
    x, y = _toy_data(cfg)
    rounds = 4 if quick else 10
    cap = 8 * rounds

    xe, ye = _toy_data(cfg, n=128, seed=99)
    ex, ey = jnp.asarray(xe), ye

    @jax.jit
    def eval_loss(st):
        logits = cls_logits(cfg, st.base, st.peft, {"tokens": ex})
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -logp[jnp.arange(len(ey)), jnp.asarray(ey)].mean()

    # -- sync arm: full participation; each round waits for its slowest
    # cohort member under the population's compute + uplink model
    pop = ClientPopulation(x, y, n_clients=1000, seed=7)
    sched = CohortScheduler(pop, cohort_size=sc.n_clients_per_round,
                            over_select=1.0, seed=3)
    eng = FederationEngine(cfg, sc, task="cls", comm_mode="per_epoch",
                           wire=WireConfig(simulate=True))
    n_units = enumerate_units(state.peft).n_units
    s = state
    sync_wall, sync_evals = 0.0, []
    for r in range(rounds):
        plan = sched.plan_round(r, n_units, sc.seed)
        bx, by = sched.round_batch(plan, B)
        s, _, _ = eng.run_round(s, plan, {"tokens": bx, "labels": by})
        sync_wall += max(pop.compute_seconds(int(c), r, WORK_S)
                         + pop.uplink_seconds(int(c), r)
                         for c in plan.client_ids)
        sync_evals.append(float(eval_loss(s)))
    # the target is the BEST point sync ever reached, not just its last —
    # async has to beat sync's whole trajectory, not a noisy endpoint
    target = min(sync_evals)

    # -- async arm: same population, fresh engine, same simulated wall
    # budget; record the first version whose held-out loss matches the
    # sync run's best
    pop2 = ClientPopulation(x, y, n_clients=1000, seed=7)
    aeng = AsyncFederationEngine(
        cfg, sc, pop2, task="cls", comm_mode="per_epoch",
        async_cfg=AsyncConfig(buffer_size=4, staleness_decay=0.5,
                              concurrency=sc.n_clients_per_round,
                              work_seconds=WORK_S, seed=11),
        wire=WireConfig(simulate=True))
    s2 = state
    versions, report, cur = 0, None, float("inf")
    async_evals, t_match = [], None
    while versions < cap:
        s2, _, report = aeng.run_version(s2, batch_size=B)
        versions += 1
        cur = float(eval_loss(s2))
        async_evals.append(cur)
        if t_match is None and cur <= target:
            t_match = float(report.sim_time_s)
        if report.sim_time_s >= sync_wall:
            break
    return {
        "arch": ARCH,
        "comm_mode": "per_epoch",
        "work_s": WORK_S,
        "sync": {"rounds": rounds, "wall_s": sync_wall,
                 "final_loss": sync_evals[-1], "best_loss": target,
                 "updates_applied": rounds * sc.n_clients_per_round},
        "async": {"versions": versions,
                  "wall_s": float(report.sim_time_s),
                  "final_loss": cur,
                  "best_loss": min(async_evals),
                  "wall_s_to_match": t_match,
                  "matched": t_match is not None,
                  "utilization": report.utilization,
                  "staleness_mean": float(np.mean(report.staleness))
                  if report.staleness else 0.0},
        "speedup": sync_wall / t_match if t_match else 0.0,
    }


def bench_utilization() -> dict:
    """Useful-compute fraction at 10^6 logical clients (pure event sim —
    no model math, so the full scale runs even in --quick)."""
    rng = np.random.default_rng(0)
    x = rng.integers(0, 100, size=(256, 16), dtype=np.int64)
    y = rng.integers(0, 4, size=(256,), dtype=np.int64)
    pop = ClientPopulation(x, y, n_clients=SCALE_CLIENTS, seed=7)

    sync_rows = []
    for q in SYNC_QUANTILES:
        rep = simulate_sync_utilization(pop, cohort=64, rounds=40,
                                        deadline_quantile=q,
                                        dropout_rate=0.1, work_s=WORK_S,
                                        seed=5)
        row = rep.to_doc()
        row["deadline_quantile"] = q
        sync_rows.append(row)
        print(f"  sync q{q}: util={rep.utilization:.3f} "
              f"upd/h={row['updates_per_sim_hour']:.0f}")

    arep = simulate_async_utilization(pop, concurrency=64, buffer_size=16,
                                      server_steps=160, dropout_rate=0.1,
                                      work_s=WORK_S, seed=5)
    async_row = arep.to_doc()
    print(f"  async: util={arep.utilization:.3f} "
          f"upd/h={async_row['updates_per_sim_hour']:.0f} "
          f"stale_mean={arep.staleness_mean:.2f}")

    base = next(r for r in sync_rows
                if r["deadline_quantile"] == BASELINE_QUANTILE)
    return {
        "n_clients": SCALE_CLIENTS,
        "work_s": WORK_S,
        "sync": sync_rows,
        "async": async_row,
        "baseline_quantile": BASELINE_QUANTILE,
        "utilization_ratio": arep.utilization
        / max(base["utilization"], 1e-12),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI mode: tiny training arm (scale sim runs full)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_async.json"))
    args = ap.parse_args()

    print("== wall-clock to matched loss ==")
    wall = bench_wall_clock(args.quick)
    print(f"  sync {wall['sync']['rounds']} rounds -> "
          f"loss {wall['sync']['final_loss']:.4f} "
          f"in {wall['sync']['wall_s']:.0f}s sim")
    print(f"  async {wall['async']['versions']} versions -> "
          f"loss {wall['async']['final_loss']:.4f} "
          f"in {wall['async']['wall_s']:.0f}s sim "
          f"(matched={wall['async']['matched']})")
    print(f"  speedup: {wall['speedup']:.2f}x")

    print(f"== utilization at {SCALE_CLIENTS:,} clients ==")
    util = bench_utilization()
    print(f"  ratio vs q{BASELINE_QUANTILE}: "
          f"{util['utilization_ratio']:.2f}x")

    doc = {
        "schema": "repro.bench_async/v1",
        "quick": bool(args.quick),
        "wall_clock": wall,
        "utilization": util,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
