"""Kernel-layer microbenchmarks (paper §5.3 / Limitations: the jvp
"column-by-column" overhead).

On this CPU host we cannot time the TPU kernels; instead we measure the
XLA-fused jnp reference paths and report:
  (1) fused jvp (one pass) vs 2x separate forwards — the paper reports
      PyTorch forward-AD costing MORE than 2 forwards; under XLA the fused
      dual-number pass should cost ~<= 2 forwards (DESIGN.md §2),
  (2) static FLOPs/bytes of each Pallas kernel's reference at model shapes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.lora_dual.ref import lora_dual_ref


def _time(fn, *args, n=20):
    fn(*args)  # compile+warm
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n


def main(print_csv=True):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 7)
    M, K, N, r = 1024, 1024, 1024, 8
    x = jax.random.normal(ks[0], (M, K))
    xd = jax.random.normal(ks[1], (M, K))
    w = jax.random.normal(ks[2], (K, N)) * 0.02
    a = jax.random.normal(ks[3], (K, r)) * 0.02
    ad = jax.random.normal(ks[4], (K, r)) * 0.02
    b = jax.random.normal(ks[5], (r, N)) * 0.02
    bd = jax.random.normal(ks[6], (r, N)) * 0.02

    def lora(x_, a_, b_):
        return x_ @ w + (x_ @ a_) @ b_

    fused_jvp = jax.jit(lambda: jax.jvp(lora, (x, a, b), (xd, ad, bd)))
    one_fwd = jax.jit(lambda: lora(x, a, b))
    two_fwd = jax.jit(lambda: (lora(x, a, b), lora(xd, ad, bd)))

    t_jvp = _time(fused_jvp)
    t_one = _time(one_fwd)
    t_two = _time(two_fwd)
    if print_csv:
        print(f"kernel/lora_jvp_vs_forward/fused_jvp,{t_jvp*1e6:.0f},"
              f"ratio_vs_1fwd={t_jvp/t_one:.2f} ratio_vs_2fwd={t_jvp/t_two:.2f}")
        print(f"kernel/lora_jvp_vs_forward/one_forward,{t_one*1e6:.0f},")
        print(f"kernel/lora_jvp_vs_forward/two_forwards,{t_two*1e6:.0f},")

    # correctness spot check against the kernel oracle
    y, yd = fused_jvp()
    yr, ydr = lora_dual_ref(x, xd, w, a, ad, b, bd, 1.0)
    err = float(jnp.abs(y - yr).max() + jnp.abs(yd - ydr).max())
    if print_csv:
        print(f"kernel/lora_dual_oracle_err,0,max_err={err:.2e}")
    return {"t_jvp": t_jvp, "t_one": t_one, "t_two": t_two, "err": err}


if __name__ == "__main__":
    main()
