"""Kernel-layer microbenchmarks (paper §5.3 / Limitations: the jvp
"column-by-column" overhead) + the ISSUE-1 K-tangent sweep.

On this CPU host we cannot time the TPU kernels; we measure the XLA-fused
jnp paths the dispatch layer routes to on CPU, and report:

  (1) fused jvp (one pass) vs 2x separate forwards — the paper reports
      PyTorch forward-AD costing MORE than 2 forwards; under XLA the fused
      dual-number pass should cost ~<= 2 forwards,
  (2) the K-tangent forward-gradient sweep at the default
      (M,K,N,r)=(1024,1024,1024,8) LoRA-unit shapes, comparing four
      estimator executions of the SAME estimate (identical seeds):

      sequential_columnwise  K separate single-tangent passes (one jit call
                             per perturbation) — the paper's PyTorch
                             forward-AD behaviour: the frozen-weight primal
                             GEMM is recomputed for every perturbation
      sequential_fused_loop  the tangent_batch=1 fori_loop inside one jit
                             (XLA's loop-invariant code motion may hoist the
                             invariant primal — reported, not assumed)
      batched_engine         the generic batched path (linearize + vmap):
                             one primal, K stacked tangents, materialized
                             (K,M,N) tangent intermediates — the
                             materialize-then-contract baseline
      batched_fused          the batched estimate through the fused
                             contraction route (``SplitLoss`` +
                             ``forward_gradient(fused_contraction=True)``):
                             the site's K tangent columns are contracted
                             against the post-head cotangent — one primal
                             pass, rank-r per-tangent work, no (K,M,N)
                             materialization — the estimator-level mirror
                             of what the ``*_mt_jvps`` Pallas epilogue
                             kernels do blockwise on TPU

The acceptance gate (ISSUE 1): batched_fused at K=8 < 0.5x the sequential
wall time. ISSUE 2 adds ``fg_mixer_ksweep``: the same
sequential-vs-batched estimator comparison THROUGH an RWKV6 recurrence and
an SWA attention block (the dispatched sequence mixers) — the batched
engine amortizes the mixer primal across K tangents, which is what the
wkv6/swa multi-tangent Pallas kernels do blockwise on TPU. ISSUE 4 adds
the fused-vs-materialized columns: per K, the peak-live-bytes of the
traced-HLO program (buffer-assignment-style liveness walk,
``launch/hlo_analysis.py::peak_live_bytes``) for the materializing batched
engine vs the fused-contraction route, plus a fused column in the mixer
sweep. Acceptance (ISSUE 4): fused K=8 records LOWER peak live bytes AND
<= 1.0x the materialize-then-contract wall time. Results are written to
BENCH_kernels.json by benchmarks/run.py.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core.forward_grad import SplitLoss, forward_gradient
from repro.kernels.dispatch import lora_proj, swa_attend, wkv6_mix
from repro.kernels.lora_dual.ref import lora_dual_ref
from repro.launch.hlo_analysis import peak_live_bytes

M, K_DIM, N, R = 1024, 1024, 1024, 8
SCALE = 1.0

# mixer-block sweep shapes: big enough that the mixer primal dominates
MB, MS, MH, MHD = 2, 256, 4, 32


def _time(fn, *args, n=5):
    out = fn(*args)                      # compile+warm
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n


def _problem():
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (M, K_DIM))
    w = jax.random.normal(ks[1], (K_DIM, N)) * 0.02
    peft = {
        "A": jax.random.normal(ks[2], (K_DIM, R)) * 0.02,
        "B": jax.random.normal(ks[3], (R, N)) * 0.02,
    }
    return x, w, peft


def _bench_jvp_vs_forwards(x, w, peft, print_csv):
    a, b = peft["A"], peft["B"]
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    xd = jax.random.normal(ks[0], (M, K_DIM))
    ad = jax.random.normal(ks[1], (K_DIM, R)) * 0.02
    bd = jax.random.normal(ks[2], (R, N)) * 0.02

    def lora(x_, a_, b_):
        return x_ @ w + (x_ @ a_) @ b_

    fused_jvp = jax.jit(lambda: jax.jvp(lora, (x, a, b), (xd, ad, bd)))
    one_fwd = jax.jit(lambda: lora(x, a, b))
    two_fwd = jax.jit(lambda: (lora(x, a, b), lora(xd, ad, bd)))

    t_jvp, t_one, t_two = _time(fused_jvp), _time(one_fwd), _time(two_fwd)
    y, yd = fused_jvp()
    yr, ydr = lora_dual_ref(x, xd, w, a, ad, b, bd, 1.0)
    err = float(jnp.abs(y - yr).max() + jnp.abs(yd - ydr).max())
    if print_csv:
        print(f"kernel/lora_jvp_vs_forward/fused_jvp,{t_jvp*1e6:.0f},"
              f"ratio_vs_1fwd={t_jvp/t_one:.2f} ratio_vs_2fwd={t_jvp/t_two:.2f}")
        print(f"kernel/lora_jvp_vs_forward/one_forward,{t_one*1e6:.0f},")
        print(f"kernel/lora_jvp_vs_forward/two_forwards,{t_two*1e6:.0f},")
        print(f"kernel/lora_dual_oracle_err,0,max_err={err:.2e}")
    return {"fused_jvp_us": t_jvp * 1e6, "one_forward_us": t_one * 1e6,
            "two_forwards_us": t_two * 1e6, "oracle_max_err": err}


def _bench_fg_ksweep(x, w, peft, k_values, print_csv):
    """Time-per-estimate of ∇_{A,B} mean(y²), y = x@W + s(x@A)@B, across the
    four execution strategies (identical estimate per seed)."""

    def loss_of(p):
        y = lora_proj(x, w, p["A"], p["B"], SCALE)
        return jnp.mean(y * y)

    key = jax.random.PRNGKey(7)

    # -- sequential, column by column: one jit call per perturbation, the
    # estimate accumulated across calls. Samples the SAME v_i =
    # masked_perturbation(fold_in(key, i)) as the batched paths and does the
    # full estimator work (g accumulation + 1/K average), so all strategies
    # compute the identical estimate per seed. --
    from repro.core.forward_grad import masked_perturbation

    @jax.jit
    def one_col(i, key, p):
        v = masked_perturbation(jax.random.fold_in(key, i), p)
        loss, jvp = jax.jvp(loss_of, (p,), (v,))
        return loss, jax.tree.map(lambda vi: jvp * vi, v), jvp

    tree_add = jax.jit(lambda a, b: jax.tree.map(jnp.add, a, b))

    rows = []
    for K in k_values:
        def columnwise(key, p, K=K):
            g, jvps = None, []
            for i in range(K):
                loss, gi, jvp = one_col(jnp.int32(i), key, p)
                g = gi if g is None else tree_add(g, gi)
                jvps.append(jvp)
            g = jax.tree.map(lambda x: x / K, g)
            return loss, g, jnp.stack(jvps)

        # -- sequential fori_loop / batched engine / chunked: one jit each --
        seq_loop = jax.jit(lambda k, p, K=K: forward_gradient(
            loss_of, p, k, k_perturbations=K, tangent_batch=1))
        batched = jax.jit(lambda k, p, K=K: forward_gradient(
            loss_of, p, k, k_perturbations=K))

        # -- batched through the fused contraction route: the estimator
        # reverses the tiny post-head once for gy and contracts the site's
        # K tangent columns without materializing them --
        split = SplitLoss(lambda p: ((x, w, p["A"], p["B"]), None), "lora",
                          lambda y, ctx, p: jnp.mean(y * y), scale=SCALE,
                          x_has_tangent=False)
        batched_fused = jax.jit(lambda k, p, K=K: forward_gradient(
            split, p, k, k_perturbations=K, fused_contraction=True))

        # correctness: all four produce the same estimate for this seed
        _, g_ref, j_ref = batched(key, peft)
        _, g_fused, j_fused = batched_fused(key, peft)
        _, g_col, j_col = columnwise(key, peft)
        jvp_err = float(jnp.abs(j_ref - j_fused).max()
                        / (jnp.abs(j_ref).max() + 1e-12))
        col_err = float(jnp.abs(j_ref - j_col).max()
                        / (jnp.abs(j_ref).max() + 1e-12))

        t_col = _time(columnwise, key, peft)
        t_loop = _time(seq_loop, key, peft)
        t_bat = _time(batched, key, peft)
        t_fused = _time(batched_fused, key, peft)
        # fused-vs-materialized peak-live-bytes of the compiled programs
        # (buffer-assignment-style liveness walk over the traced HLO): the
        # materializing engine carries the (K, M, N) tangent stack, the
        # fused route never forms it
        peak_mat = peak_live_bytes(
            batched.lower(key, peft).compile().as_text())
        peak_fused = peak_live_bytes(
            batched_fused.lower(key, peft).compile().as_text())
        row = {
            "K": K,
            "sequential_columnwise_us": t_col * 1e6,
            "sequential_fused_loop_us": t_loop * 1e6,
            "batched_engine_us": t_bat * 1e6,
            "batched_fused_us": t_fused * 1e6,
            "ratio_fused_vs_columnwise": t_fused / t_col,
            "ratio_fused_vs_loop": t_fused / t_loop,
            "ratio_fused_vs_engine": t_fused / t_bat,
            "peak_live_mb_materialized": peak_mat / 1e6,
            "peak_live_mb_fused": peak_fused / 1e6,
            "ratio_peak_fused_vs_materialized": peak_fused / peak_mat,
            "jvp_rel_err_fused_vs_engine": jvp_err,
            "jvp_rel_err_columnwise_vs_engine": col_err,
        }
        rows.append(row)
        if print_csv:
            print(f"kernel/fg_ksweep/K={K}/sequential_columnwise,"
                  f"{t_col*1e6:.0f},")
            print(f"kernel/fg_ksweep/K={K}/sequential_fused_loop,"
                  f"{t_loop*1e6:.0f},")
            print(f"kernel/fg_ksweep/K={K}/batched_engine,{t_bat*1e6:.0f},")
            print(f"kernel/fg_ksweep/K={K}/batched_fused,{t_fused*1e6:.0f},"
                  f"ratio_vs_columnwise={t_fused/t_col:.2f} "
                  f"ratio_vs_loop={t_fused/t_loop:.2f} jvp_err={jvp_err:.1e}")
            print(f"kernel/fg_ksweep/K={K}/peak_live_bytes,0,"
                  f"materialized={peak_mat/1e6:.1f}MB "
                  f"fused={peak_fused/1e6:.1f}MB "
                  f"ratio={peak_fused/peak_mat:.2f}")
    return rows


def _mixer_problem(mixer):
    """A one-block loss through the dispatched sequence mixer, with a LoRA
    projection feeding it so perturbations carry tangents into the mixer."""
    ks = jax.random.split(jax.random.PRNGKey(11), 8)
    B, S, H, hd = MB, MS, MH, MHD
    D = H * hd
    x = jax.random.normal(ks[0], (B, S, D)) * 0.3
    wp = [jax.random.normal(ks[1 + i], (D, D)) * 0.05 for i in range(3)]
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    wdec = jax.nn.sigmoid(jax.random.normal(ks[5], (B, S, H, hd)))
    peft = {"A": jax.random.normal(ks[6], (D, R)) * 0.05,
            "B": jax.random.normal(ks[7], (R, D)) * 0.05}

    def loss_of(p):
        r = lora_proj(x, wp[0], p["A"], p["B"], SCALE)
        k = (x @ wp[1]).reshape(B, S, H, hd)
        v = (x @ wp[2]).reshape(B, S, H, hd)
        if mixer == "rwkv6":
            y = wkv6_mix(r.reshape(B, S, H, hd), k, v, wdec, u)
        else:
            y = swa_attend(r.reshape(B, S, H, hd).transpose(0, 2, 1, 3),
                           k.transpose(0, 2, 1, 3),
                           v.transpose(0, 2, 1, 3), 128)
        return jnp.mean(y * y)

    def pre(p):
        r = lora_proj(x, wp[0], p["A"], p["B"], SCALE)
        k = (x @ wp[1]).reshape(B, S, H, hd)
        v = (x @ wp[2]).reshape(B, S, H, hd)
        if mixer == "rwkv6":
            return (r.reshape(B, S, H, hd), k, v, wdec, u), None
        return (r.reshape(B, S, H, hd).transpose(0, 2, 1, 3),
                k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)), None

    split = SplitLoss(pre, "wkv6" if mixer == "rwkv6" else "swa",
                      lambda y, ctx, p: jnp.mean(y * y), window=128)
    return loss_of, split, peft


def _bench_mixer_ksweep(k_values, print_csv):
    """Estimator wall time through an RWKV6 recurrence and an SWA attention
    block, three executions of the same estimate (cf. ``_bench_fg_ksweep``):

      sequential_columnwise  one jit call per perturbation — the paper's
                             column-by-column jvp behaviour (the mixer
                             primal recomputed K times)
      sequential_fused_loop  tangent_batch=1 fori_loop inside one jit (XLA
                             may hoist loop-invariant primal work)
      batched_engine         linearize + vmap: ONE mixer primal, K stacked
                             tangents — the execution the wkv6/swa
                             multi-tangent Pallas kernels realize blockwise
                             on TPU

    Measures the jnp paths the dispatch layer routes to on CPU."""
    from repro.core.forward_grad import masked_perturbation

    out = {}
    key = jax.random.PRNGKey(13)
    for mixer in ("rwkv6", "swa"):
        loss_of, split, peft = _mixer_problem(mixer)

        @jax.jit
        def one_col(i, key, p, loss_of=loss_of):
            v = masked_perturbation(jax.random.fold_in(key, i), p)
            loss, jvp = jax.jvp(loss_of, (p,), (v,))
            return loss, jax.tree.map(lambda vi: jvp * vi, v), jvp

        tree_add = jax.jit(lambda a, b: jax.tree.map(jnp.add, a, b))
        rows = []
        for K in k_values:
            def columnwise(key, p, K=K):
                g, jvps = None, []
                for i in range(K):
                    loss, gi, jvp = one_col(jnp.int32(i), key, p)
                    g = gi if g is None else tree_add(g, gi)
                    jvps.append(jvp)
                return loss, jax.tree.map(lambda t: t / K, g), jnp.stack(jvps)

            seq = jax.jit(lambda k_, p, K=K: forward_gradient(
                loss_of, p, k_, k_perturbations=K, tangent_batch=1))
            bat = jax.jit(lambda k_, p, K=K: forward_gradient(
                loss_of, p, k_, k_perturbations=K))
            fused = jax.jit(lambda k_, p, K=K: forward_gradient(
                split, p, k_, k_perturbations=K, fused_contraction=True))
            _, _, j_c = columnwise(key, peft)
            _, _, j_b = bat(key, peft)
            _, _, j_f = fused(key, peft)
            jvp_err = float(jnp.abs(j_c - j_b).max()
                            / (jnp.abs(j_c).max() + 1e-12))
            fused_err = float(jnp.abs(j_f - j_b).max()
                              / (jnp.abs(j_b).max() + 1e-12))
            t_col = _time(columnwise, key, peft)
            t_seq = _time(seq, key, peft)
            t_bat = _time(bat, key, peft)
            t_fused = _time(fused, key, peft)
            peak_mat = peak_live_bytes(
                bat.lower(key, peft).compile().as_text())
            peak_fused = peak_live_bytes(
                fused.lower(key, peft).compile().as_text())
            rows.append({
                "K": K,
                "sequential_columnwise_us": t_col * 1e6,
                "sequential_fused_loop_us": t_seq * 1e6,
                "batched_engine_us": t_bat * 1e6,
                "batched_fused_us": t_fused * 1e6,
                "ratio_batched_vs_columnwise": t_bat / t_col,
                "ratio_batched_vs_loop": t_bat / t_seq,
                "ratio_fused_vs_engine": t_fused / t_bat,
                "peak_live_mb_materialized": peak_mat / 1e6,
                "peak_live_mb_fused": peak_fused / 1e6,
                "ratio_peak_fused_vs_materialized": peak_fused / peak_mat,
                "jvp_rel_err": jvp_err,
                "jvp_rel_err_fused_vs_engine": fused_err,
            })
            if print_csv:
                print(f"kernel/fg_mixer_ksweep/{mixer}/K={K}/"
                      f"sequential_columnwise,{t_col*1e6:.0f},")
                print(f"kernel/fg_mixer_ksweep/{mixer}/K={K}/"
                      f"sequential_fused_loop,{t_seq*1e6:.0f},")
                print(f"kernel/fg_mixer_ksweep/{mixer}/K={K}/batched_engine,"
                      f"{t_bat*1e6:.0f},ratio_vs_columnwise={t_bat/t_col:.2f}"
                      f" ratio_vs_loop={t_bat/t_seq:.2f} "
                      f"jvp_err={jvp_err:.1e}")
                print(f"kernel/fg_mixer_ksweep/{mixer}/K={K}/batched_fused,"
                      f"{t_fused*1e6:.0f},ratio_vs_engine={t_fused/t_bat:.2f}"
                      f" peak_mat={peak_mat/1e6:.1f}MB "
                      f"peak_fused={peak_fused/1e6:.1f}MB "
                      f"jvp_err={fused_err:.1e}")
        out[mixer] = rows
    return out


def _bench_fullmodel_ksweep(k_values, print_csv):
    """FULL-model fused-vs-standard sweep (ISSUE 5): the registry lm/cls
    training losses — whose final mixer site now sits OUTSIDE the layer
    scan (split-forward refactor) — estimated with and without
    ``fused_contraction``. Reports wall time and the compiled program's
    peak-live-bytes for both routes; the fused route reverses the post-head
    once and contracts the site's K tangent columns without materializing
    them (nor pushing K stacked tangents through the loss head)."""
    from repro.configs import SpryConfig, get_config, reduce_config
    from repro.models.registry import get_loss_fn, get_model
    from repro.peft import init_peft

    out = {}
    B, S = 2, 64
    for arch, task in (("llama2-7b", "cls"), ("llama2-7b", "lm"),
                       ("rwkv6-1.6b", "lm")):
        cfg = reduce_config(get_config(arch))
        key = jax.random.PRNGKey(3)
        model = get_model(cfg)
        base = model.init_base(cfg, key)
        peft = jax.tree.map(lambda x: x.astype(jnp.float32),
                            init_peft(cfg, key, SpryConfig()))
        batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
                 "labels": jax.random.randint(key, (B,), 0, cfg.n_classes)}

        def plain(p, batch=batch, cfg=cfg, base=base, task=task):
            return get_loss_fn(task)(cfg, base, p, batch)

        split = get_loss_fn(task, split=True)(cfg, base, batch)
        rows = []
        for K in k_values:
            std = jax.jit(lambda k_, p, K=K: forward_gradient(
                plain, p, k_, k_perturbations=K))
            fused = jax.jit(lambda k_, p, K=K, split=split: forward_gradient(
                split, p, k_, k_perturbations=K, fused_contraction=True))
            _, _, j_s = std(key, peft)
            _, _, j_f = fused(key, peft)
            jvp_err = float(jnp.abs(j_f - j_s).max()
                            / (jnp.abs(j_s).max() + 1e-12))
            t_std = _time(std, key, peft)
            t_fused = _time(fused, key, peft)
            peak_std = peak_live_bytes(
                std.lower(key, peft).compile().as_text())
            peak_fused = peak_live_bytes(
                fused.lower(key, peft).compile().as_text())
            rows.append({
                "K": K,
                "standard_us": t_std * 1e6,
                "fused_us": t_fused * 1e6,
                "ratio_time_fused_vs_standard": t_fused / t_std,
                "peak_live_mb_standard": peak_std / 1e6,
                "peak_live_mb_fused": peak_fused / 1e6,
                "ratio_peak_fused_vs_standard": peak_fused / peak_std,
                "jvp_rel_err": jvp_err,
            })
            if print_csv:
                print(f"kernel/fg_fullmodel/{arch}/{task}/K={K},"
                      f"{t_fused*1e6:.0f},time_ratio={t_fused/t_std:.2f} "
                      f"peak_std={peak_std/1e6:.1f}MB "
                      f"peak_fused={peak_fused/1e6:.1f}MB "
                      f"peak_ratio={peak_fused/peak_std:.2f} "
                      f"jvp_err={jvp_err:.1e}")
        out[f"{arch}/{task}"] = rows
    return out


def main(print_csv=True, quick=False, json_path=None):
    x, w, peft = _problem()
    result = {
        "shapes": {"M": M, "K": K_DIM, "N": N, "r": R},
        "jvp_vs_forward": _bench_jvp_vs_forwards(x, w, peft, print_csv),
        "fg_ksweep": _bench_fg_ksweep(
            x, w, peft, (1, 8) if quick else (1, 2, 4, 8, 16), print_csv),
        "mixer_shapes": {"B": MB, "S": MS, "H": MH, "hd": MHD},
        "fg_mixer_ksweep": _bench_mixer_ksweep(
            (1, 8) if quick else (1, 2, 4, 8), print_csv),
        "fg_fullmodel": _bench_fullmodel_ksweep(
            (1, 8) if quick else (1, 4, 8), print_csv),
    }
    k8 = next((r for r in result["fg_ksweep"] if r["K"] == 8), None)
    if k8 is not None:
        result["acceptance"] = {
            "criterion": "batched K=8 estimate < 0.5x sequential wall time",
            "ratio_fused_vs_columnwise": k8["ratio_fused_vs_columnwise"],
            "ratio_fused_vs_loop": k8["ratio_fused_vs_loop"],
            "pass": k8["ratio_fused_vs_columnwise"] < 0.5,
        }
        if print_csv:
            print(f"kernel/fg_ksweep/acceptance,0,"
                  f"K=8 fused/columnwise={k8['ratio_fused_vs_columnwise']:.2f}"
                  f" (<0.5 required) pass={result['acceptance']['pass']}")
        result["fused_epilogue_acceptance"] = {
            "criterion": ("fused K=8: lower peak live bytes AND <= 1.0x "
                          "wall time vs the materialize-then-contract "
                          "batched engine"),
            "ratio_peak_fused_vs_materialized":
                k8["ratio_peak_fused_vs_materialized"],
            "ratio_time_fused_vs_engine": k8["ratio_fused_vs_engine"],
            "pass": (k8["ratio_peak_fused_vs_materialized"] < 1.0
                     and k8["ratio_fused_vs_engine"] <= 1.0),
        }
        if print_csv:
            print(f"kernel/fg_ksweep/fused_epilogue_acceptance,0,"
                  f"K=8 peak ratio="
                  f"{k8['ratio_peak_fused_vs_materialized']:.2f} time ratio="
                  f"{k8['ratio_fused_vs_engine']:.2f} "
                  f"pass={result['fused_epilogue_acceptance']['pass']}")
    mixer_acc = {}
    for mixer, rows in result["fg_mixer_ksweep"].items():
        k8m = next((r for r in rows if r["K"] == 8), None)
        if k8m is not None:
            mixer_acc[mixer] = {
                "criterion": ("batched K=8 estimate < 1x the sequential "
                              "column-by-column wall time"),
                "ratio_batched_vs_columnwise":
                    k8m["ratio_batched_vs_columnwise"],
                "pass": k8m["ratio_batched_vs_columnwise"] < 1.0,
            }
            if print_csv:
                print(f"kernel/fg_mixer_ksweep/{mixer}/acceptance,0,"
                      f"K=8 batched/columnwise="
                      f"{k8m['ratio_batched_vs_columnwise']:.2f} (<1 "
                      f"required) pass={mixer_acc[mixer]['pass']}")
    if mixer_acc:
        result["mixer_acceptance"] = mixer_acc
    # CPU-mirror scope: the swa 'jnp' contract materializes-and-contracts
    # (the no-tangent-stack property of the swa epilogue is kernel-backend
    # only — see kernels/dispatch.py), so the dense rows are informational;
    # the wkv6 mirror realizes the reduction on CPU too and gates the
    # acceptance. On TPU all site families run the in-kernel epilogues.
    rows_rwkv = result["fg_fullmodel"].get("rwkv6-1.6b/lm", [])
    k8f = next((r for r in rows_rwkv if r["K"] == 8), None)
    if k8f is not None:
        result["fullmodel_acceptance"] = {
            "criterion": ("full-model (registry lm_loss, split forward) "
                          "fused K=8 records lower peak live bytes than "
                          "the materializing engine (CPU mirrors; wkv6 "
                          "family — the swa jnp mirror "
                          "materializes-and-contracts by design)"),
            "ratio_peak_fused_vs_standard":
                k8f["ratio_peak_fused_vs_standard"],
            "ratio_time_fused_vs_standard":
                k8f["ratio_time_fused_vs_standard"],
            "pass": k8f["ratio_peak_fused_vs_standard"] < 1.0,
        }
        if print_csv:
            print(f"kernel/fg_fullmodel/acceptance,0,"
                  f"rwkv6 lm K=8 peak_ratio="
                  f"{k8f['ratio_peak_fused_vs_standard']:.2f} (<1 required)"
                  f" pass={result['fullmodel_acceptance']['pass']}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=2)
        if print_csv:
            print(f"# wrote {json_path}")
    return result


if __name__ == "__main__":
    main(json_path="BENCH_kernels.json")
